//! Deterministic pure-rust execution backend for the serving coordinator.
//!
//! The live path executes quantized inference through compiled PJRT
//! artifacts; when those (or the XLA runtime itself) are unavailable, the
//! serving stack would previously be untestable offline. [`SimBackend`]
//! closes that gap: it builds synthetic weights from a network *geometry*
//! (`nets::Network`) and executes the same quantized-forward ABI — per-layer
//! `w_bits`/`a_bits` vectors, fixed-size batches — with fake-quantization
//! identical in structure to the Pallas kernels (symmetric per-tensor
//! weight quantization, post-ReLU activation quantization).
//!
//! Fully-connected layers run directly through the blocked matmul kernel
//! (`runtime::gemm`); conv layers are lowered to im2col + the same kernel,
//! exactly the paper's §II view of a conv as a lowered R×N weight matrix
//! streaming W² input vectors. Inter-layer max pooling is inferred from the
//! geometry (the benchmark nets list only weight-bearing layers, so a
//! spatial shrink between consecutive convs — or a conv followed by a
//! smaller FC — implies the pooling stage that the real nets put there).
//! Networks whose layers do not chain sequentially (e.g. ResNet residual
//! projections) are rejected by the [`SimBackend::supports`] capability
//! query, which callers use to report a typed error *before* building a
//! backend.
//!
//! Weights are synthetic (seeded He-scaled Gaussians), so logits carry no
//! trained meaning; what the backend faithfully reproduces is everything
//! the coordinator cares about: shapes, batching, per-layer bit-width
//! plumbing, determinism, and failure modes.

use crate::nets::{Layer, LayerKind, Network};
use crate::runtime::gemm::{self, ConvGeom, PackedMat};
use crate::util::prng::Rng;
use anyhow::{bail, Result};

/// Output positions lowered per im2col + matmul call: bounds the patch
/// scratch buffer to ~`CONV_CHUNK · patch_len` floats regardless of the
/// input resolution (a full 224×224 im2col would be hundreds of MB).
const CONV_CHUNK: usize = 128;

/// How one network layer executes on the sim backend.
#[derive(Clone, Copy, Debug)]
enum LayerExec {
    /// Dense layer: one matmul over the batch.
    Fc { in_f: usize, out_f: usize },
    /// Conv layer lowered to im2col + matmul, followed by `pool × pool`
    /// max pooling (1 = none) to reach the next layer's input grid.
    Conv { geom: ConvGeom, pool: usize },
}

impl LayerExec {
    /// (lowered rows, lowered cols) of the layer's weight matrix — the
    /// same R×N the paper's tile equation sees (`nets::Layer::lowered_*`).
    fn lowered_dims(&self) -> (usize, usize) {
        match *self {
            LayerExec::Fc { in_f, out_f } => (in_f, out_f),
            LayerExec::Conv { geom, .. } => (geom.patch_len(), geom.out_c),
        }
    }

    fn in_features(&self) -> usize {
        match *self {
            LayerExec::Fc { in_f, .. } => in_f,
            LayerExec::Conv { geom, .. } => geom.in_features(),
        }
    }

    fn out_features(&self) -> usize {
        match *self {
            LayerExec::Fc { out_f, .. } => out_f,
            LayerExec::Conv { geom, pool } => {
                let s = geom.out_hw / pool;
                geom.out_c * s * s
            }
        }
    }
}

/// Pure-rust quantized-forward backend (see module docs).
pub struct SimBackend {
    name: String,
    layers: Vec<LayerExec>,
    /// Row-major lowered [rows][cols] synthetic weights per layer.
    weights: Vec<Vec<f32>>,
    eval_batch: usize,
    input_dim: usize,
    num_classes: usize,
    /// Packed quantized weights for the last-seen `w_bits` vector.
    cache: Option<(Vec<f32>, Vec<PackedMat>)>,
}

impl SimBackend {
    /// Capability query: can the sim backend execute this network? `Err`
    /// carries the precise reason (e.g. a residual projection that breaks
    /// the sequential chain); `serve` surfaces it as a typed `ApiError`
    /// instead of a runtime string.
    pub fn supports(net: &Network) -> Result<(), String> {
        plan(net).map(|_| ())
    }

    /// Build from a network geometry. Any network accepted by
    /// [`SimBackend::supports`] works — fully-connected chains and
    /// sequential conv topologies (MLPs, VGG-style nets).
    pub fn from_network(net: &Network, eval_batch: usize, seed: u64) -> Result<SimBackend, String> {
        if eval_batch == 0 {
            return Err("eval_batch must be >= 1".into());
        }
        let layers = plan(net)?;
        let mut rng = Rng::new(seed ^ 0x51A1_BACC);
        let weights = layers
            .iter()
            .map(|l| {
                let (rows, cols) = l.lowered_dims();
                let scale = (2.0 / rows as f64).sqrt();
                (0..rows * cols)
                    .map(|_| (rng.normal() * scale) as f32)
                    .collect()
            })
            .collect();
        let input_dim = layers[0].in_features();
        let num_classes = layers[layers.len() - 1].out_features();
        Ok(SimBackend {
            name: net.name.clone(),
            layers,
            weights,
            eval_batch,
            input_dim,
            num_classes,
            cache: None,
        })
    }

    /// The network name this backend was built from.
    pub fn network_name(&self) -> &str {
        &self.name
    }

    fn quantized_weights(&mut self, w_bits: &[f32]) -> &[PackedMat] {
        let stale = match &self.cache {
            Some((bits, _)) => bits.as_slice() != w_bits,
            None => true,
        };
        if stale {
            let packed = self
                .weights
                .iter()
                .zip(&self.layers)
                .zip(w_bits)
                .map(|((w, l), &b)| {
                    let (rows, cols) = l.lowered_dims();
                    PackedMat::pack(&quantize_symmetric(w, b as u32), rows, cols)
                })
                .collect();
            self.cache = Some((w_bits.to_vec(), packed));
        }
        &self.cache.as_ref().unwrap().1
    }
}

/// Resolve a network into per-layer execution plans, or explain why the
/// sim backend cannot run it. Checks that consecutive layers chain (channel
/// and feature counts match) and infers inter-layer pooling factors.
fn plan(net: &Network) -> Result<Vec<LayerExec>, String> {
    if net.layers.is_empty() {
        return Err(format!("network '{}' has no layers", net.name));
    }
    let mut execs: Vec<LayerExec> = Vec::with_capacity(net.layers.len());
    // What the previous layer produces: feature count, CHW grid when the
    // producer is spatial, and the producer's name (for error messages).
    let mut prev: Option<(usize, Option<(usize, usize)>, &str)> = None;
    for (idx, l) in net.layers.iter().enumerate() {
        let exec = match l.kind {
            LayerKind::Linear { in_f, out_f } => {
                let (in_f, out_f) = (in_f as usize, out_f as usize);
                if in_f == 0 || out_f == 0 {
                    return Err(format!("{}: layer '{}' has a zero dim", net.name, l.name));
                }
                if let Some((feat, _, pname)) = prev {
                    if feat != in_f {
                        return Err(format!(
                            "{}: layer '{}' expects {} input features but '{}' produces {}",
                            net.name, l.name, in_f, pname, feat
                        ));
                    }
                }
                LayerExec::Fc { in_f, out_f }
            }
            LayerKind::Conv2d {
                in_c,
                out_c,
                kernel,
                stride,
                padding,
                in_hw,
            } => {
                let geom = ConvGeom {
                    in_c: in_c as usize,
                    out_c: out_c as usize,
                    kernel: kernel as usize,
                    stride: stride as usize,
                    padding: padding as usize,
                    in_hw: in_hw as usize,
                    out_hw: l.out_hw() as usize,
                };
                if geom.in_c == 0
                    || geom.out_c == 0
                    || geom.kernel == 0
                    || geom.stride == 0
                    || geom.out_hw == 0
                {
                    return Err(format!("{}: layer '{}' has a zero dim", net.name, l.name));
                }
                if let Some((feat, grid, pname)) = prev {
                    match grid {
                        Some((c, hw)) if (c, hw) != (geom.in_c, geom.in_hw) => {
                            return Err(format!(
                                "{}: layer '{}' expects {}ch@{}x{} but '{}' produces \
                                 {}ch@{}x{} — sim backend executes sequential \
                                 topologies only",
                                net.name,
                                l.name,
                                geom.in_c,
                                geom.in_hw,
                                geom.in_hw,
                                pname,
                                c,
                                hw,
                                hw
                            ));
                        }
                        None if feat != geom.in_features() => {
                            return Err(format!(
                                "{}: layer '{}' expects {} input features but '{}' \
                                 produces {}",
                                net.name,
                                l.name,
                                geom.in_features(),
                                pname,
                                feat
                            ));
                        }
                        _ => {}
                    }
                }
                let pool = match net.layers.get(idx + 1) {
                    None => 1,
                    Some(next) => pool_factor(&geom, l, next, &net.name)?,
                };
                LayerExec::Conv { geom, pool }
            }
        };
        prev = Some(match exec {
            LayerExec::Fc { out_f, .. } => (out_f, None, l.name.as_str()),
            LayerExec::Conv { geom, pool } => {
                let s = geom.out_hw / pool;
                (geom.out_c * s * s, Some((geom.out_c, s)), l.name.as_str())
            }
        });
        execs.push(exec);
    }
    Ok(execs)
}

/// Inter-layer pooling factor between a conv layer and its successor: the
/// integer grid shrink that makes the conv's output match the successor's
/// expected input (1 when the grids already agree).
fn pool_factor(g: &ConvGeom, l: &Layer, next: &Layer, net: &str) -> Result<usize, String> {
    let target_hw = match next.kind {
        LayerKind::Conv2d { in_c, in_hw, .. } => {
            if in_c as usize != g.out_c {
                return Err(format!(
                    "{net}: conv '{}' produces {} channels but '{}' expects {} — \
                     sim backend executes sequential topologies only",
                    l.name, g.out_c, next.name, in_c
                ));
            }
            in_hw as usize
        }
        LayerKind::Linear { in_f, .. } => {
            // The FC layer flattens a CHW volume: in_f = out_c · s².
            let in_f = in_f as usize;
            let s = if in_f % g.out_c == 0 {
                integer_sqrt(in_f / g.out_c)
            } else {
                None
            };
            match s {
                Some(s) => s,
                None => {
                    return Err(format!(
                        "{net}: FC layer '{}' input {} does not flatten the {} \
                         channels conv '{}' produces",
                        next.name, in_f, g.out_c, l.name
                    ));
                }
            }
        }
    };
    if target_hw == 0 || target_hw > g.out_hw || g.out_hw % target_hw != 0 {
        return Err(format!(
            "{net}: conv '{}' output grid {}x{} cannot pool down to the {}x{} \
             grid '{}' expects",
            l.name, g.out_hw, g.out_hw, target_hw, target_hw, next.name
        ));
    }
    Ok(g.out_hw / target_hw)
}

/// Exact integer square root, if `n` is a perfect square.
fn integer_sqrt(n: usize) -> Option<usize> {
    let s = (n as f64).sqrt().round() as usize;
    if s.checked_mul(s) == Some(n) {
        Some(s)
    } else {
        None
    }
}

/// One conv layer over the batch: per sample, chunked im2col + blocked
/// matmul into a CHW activation volume, then optional ReLU and pooling.
fn conv_forward(
    h: &[f32],
    b: usize,
    g: &ConvGeom,
    pool: usize,
    w: &PackedMat,
    relu: bool,
) -> Vec<f32> {
    let in_feat = g.in_features();
    let npos = g.num_positions();
    let pl = g.patch_len();
    let pooled_hw = g.out_hw / pool;
    let out_feat = g.out_c * pooled_hw * pooled_hw;
    let chunk = CONV_CHUNK.min(npos);
    let mut out = vec![0f32; b * out_feat];
    let mut patches = vec![0f32; chunk * pl];
    let mut prod = vec![0f32; chunk * g.out_c];
    let mut conv_out = vec![0f32; g.out_c * npos];
    for s in 0..b {
        let xs = &h[s * in_feat..(s + 1) * in_feat];
        let mut pos0 = 0;
        while pos0 < npos {
            let m = chunk.min(npos - pos0);
            gemm::im2col_chunk(xs, g, pos0, m, &mut patches[..m * pl]);
            gemm::matmul_blocked(&patches[..m * pl], w, m, &mut prod[..m * g.out_c]);
            // The matmul emits position-major rows (HWC); the activation
            // layout between layers is CHW, so transpose while scattering.
            for (p, row) in prod[..m * g.out_c].chunks_exact(g.out_c).enumerate() {
                for (oc, &v) in row.iter().enumerate() {
                    conv_out[oc * npos + pos0 + p] = v;
                }
            }
            pos0 += m;
        }
        if relu {
            relu_inplace(&mut conv_out);
        }
        let dst = &mut out[s * out_feat..(s + 1) * out_feat];
        if pool == 1 {
            dst.copy_from_slice(&conv_out);
        } else {
            gemm::max_pool(&conv_out, g.out_c, g.out_hw, pool, dst);
        }
    }
    out
}

fn relu_inplace(h: &mut [f32]) {
    for v in h.iter_mut() {
        *v = v.max(0.0);
    }
}

/// Symmetric per-tensor fake-quantization to `bits` (signed levels).
fn quantize_symmetric(w: &[f32], bits: u32) -> Vec<f32> {
    let max = w.iter().fold(0f32, |m, &v| m.max(v.abs()));
    if max == 0.0 || bits >= 24 {
        return w.to_vec();
    }
    let levels = ((1u32 << (bits.max(1) - 1)) - 1).max(1) as f32;
    let scale = max / levels;
    w.iter().map(|&v| (v / scale).round() * scale).collect()
}

/// Fake-quantization of activations to `bits`. Hidden layers are post-ReLU
/// (non-negative → unsigned grid with 2^b − 1 levels); the first layer sees
/// raw client data, so signed inputs fall back to a symmetric signed grid.
fn quantize_activations(h: &mut [f32], bits: u32) {
    let max_abs = h.iter().fold(0f32, |m, &v| m.max(v.abs()));
    if max_abs == 0.0 || bits >= 24 {
        return;
    }
    let signed = h.iter().any(|&v| v < 0.0);
    let levels = if signed {
        ((1u64 << (bits.max(1) - 1)) - 1).max(1) as f32
    } else {
        ((1u64 << bits) - 1).max(1) as f32
    };
    let scale = max_abs / levels;
    for v in h.iter_mut() {
        *v = (*v / scale).round() * scale;
    }
}

impl crate::coordinator::InferenceBackend for SimBackend {
    fn backend_name(&self) -> &'static str {
        "sim"
    }
    fn num_layers(&self) -> usize {
        self.layers.len()
    }
    fn input_dim(&self) -> usize {
        self.input_dim
    }
    fn num_classes(&self) -> usize {
        self.num_classes
    }
    fn eval_batch(&self) -> usize {
        self.eval_batch
    }

    fn eval(&mut self, x: Vec<f32>, w_bits: Vec<f32>, a_bits: Vec<f32>) -> Result<Vec<f32>> {
        let b = self.eval_batch;
        let (dim, classes) = (self.input_dim, self.num_classes);
        if x.len() != b * dim {
            bail!("sim eval expects exactly {}x{} inputs, got {}", b, dim, x.len());
        }
        if w_bits.len() != self.layers.len() || a_bits.len() != self.layers.len() {
            bail!(
                "bit vectors must have {} entries, got w={} a={}",
                self.layers.len(),
                w_bits.len(),
                a_bits.len()
            );
        }
        let n_layers = self.layers.len();
        let layers = self.layers.clone();
        let packed = self.quantized_weights(&w_bits);

        let mut h = x;
        for (l, (exec, w)) in layers.iter().zip(packed).enumerate() {
            // Quantize this layer's input activations to a_bits[l].
            quantize_activations(&mut h, a_bits[l] as u32);
            let relu = l + 1 < n_layers; // ReLU on hidden layers only
            h = match *exec {
                LayerExec::Fc { out_f, .. } => {
                    let mut out = vec![0f32; b * out_f];
                    gemm::matmul_blocked(&h, w, b, &mut out);
                    if relu {
                        relu_inplace(&mut out);
                    }
                    out
                }
                LayerExec::Conv { geom, pool } => conv_forward(&h, b, &geom, pool, w, relu),
            };
        }
        debug_assert_eq!(h.len(), b * classes);
        Ok(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::InferenceBackend;
    use crate::nets;

    fn backend() -> SimBackend {
        SimBackend::from_network(&nets::mlp_tiny(), 4, 7).unwrap()
    }

    #[test]
    fn geometry_follows_the_network() {
        let b = backend();
        assert_eq!(b.num_layers(), 4);
        assert_eq!(b.input_dim(), 256);
        assert_eq!(b.num_classes(), 10);
        assert_eq!(b.eval_batch(), 4);
    }

    #[test]
    fn sequential_conv_networks_are_supported() {
        assert!(SimBackend::supports(&nets::conv_tiny()).is_ok());
        assert!(SimBackend::supports(&nets::vgg16()).is_ok());
        assert!(SimBackend::supports(&nets::mlp_mnist()).is_ok());
    }

    #[test]
    fn residual_networks_are_rejected_with_a_reason() {
        // ResNet downsample projections branch off the sequential chain.
        let err = SimBackend::supports(&nets::resnet::resnet18()).unwrap_err();
        assert!(err.contains("sequential"), "{err}");
        assert!(err.contains("downsample"), "{err}");
        // from_network reports the same reason.
        let err2 = SimBackend::from_network(&nets::resnet::resnet18(), 4, 7).unwrap_err();
        assert_eq!(err, err2);
    }

    #[test]
    fn channel_mismatch_is_rejected() {
        let net = nets::Network {
            name: "bad-chain".into(),
            layers: vec![
                nets::Layer::conv("c1", 3, 4, 3, 1, 1, 8),
                nets::Layer::conv("c2", 8, 4, 3, 1, 1, 8),
            ],
        };
        let err = SimBackend::supports(&net).unwrap_err();
        assert!(err.contains("channels"), "{err}");
    }

    #[test]
    fn non_square_flatten_is_rejected() {
        let net = nets::Network {
            name: "bad-flatten".into(),
            layers: vec![
                nets::Layer::conv("c1", 3, 4, 3, 1, 1, 8),
                nets::Layer::linear("fc", 4 * 3, 10), // 3 is not a square
            ],
        };
        let err = SimBackend::supports(&net).unwrap_err();
        assert!(err.contains("flatten"), "{err}");
    }

    #[test]
    fn eval_is_deterministic_and_shaped() {
        let mut a = backend();
        let mut b = backend();
        let x: Vec<f32> = (0..4 * 256).map(|i| (i % 17) as f32 / 17.0).collect();
        let bits = vec![8.0f32; 4];
        let ya = a.eval(x.clone(), bits.clone(), bits.clone()).unwrap();
        let yb = b.eval(x, bits.clone(), bits).unwrap();
        assert_eq!(ya.len(), 4 * 10);
        assert_eq!(ya, yb);
        assert!(ya.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn conv_eval_is_deterministic_and_shaped() {
        let net = nets::conv_tiny();
        let nl = net.num_layers();
        let mut a = SimBackend::from_network(&net, 2, 9).unwrap();
        let mut b = SimBackend::from_network(&net, 2, 9).unwrap();
        assert_eq!(a.input_dim(), 3 * 8 * 8);
        assert_eq!(a.num_classes(), 10);
        let x: Vec<f32> = (0..2 * 192).map(|i| ((i * 7) % 23) as f32 / 23.0 - 0.3).collect();
        let bits = vec![8.0f32; nl];
        let ya = a.eval(x.clone(), bits.clone(), bits.clone()).unwrap();
        let yb = b.eval(x, bits.clone(), bits).unwrap();
        assert_eq!(ya.len(), 2 * 10);
        assert_eq!(ya, yb);
        assert!(ya.iter().all(|v| v.is_finite()));
        assert!(ya.iter().any(|&v| v != 0.0));
    }

    #[test]
    fn bit_widths_change_the_outputs() {
        let mut b = backend();
        let x: Vec<f32> = (0..4 * 256).map(|i| ((i * 31) % 101) as f32 / 101.0).collect();
        let y8 = b.eval(x.clone(), vec![8.0; 4], vec![8.0; 4]).unwrap();
        let y2 = b.eval(x, vec![2.0; 4], vec![2.0; 4]).unwrap();
        assert_ne!(y8, y2, "quantization must affect the forward pass");
    }

    #[test]
    fn conv_bit_widths_change_the_outputs() {
        let net = nets::conv_tiny();
        let nl = net.num_layers();
        let mut b = SimBackend::from_network(&net, 2, 5).unwrap();
        let x: Vec<f32> = (0..2 * 192).map(|i| ((i * 13) % 31) as f32 / 31.0).collect();
        let y8 = b.eval(x.clone(), vec![8.0; nl], vec![8.0; nl]).unwrap();
        let y2 = b.eval(x, vec![2.0; nl], vec![2.0; nl]).unwrap();
        assert_ne!(y8, y2, "quantization must affect the conv forward pass");
    }

    #[test]
    fn wrong_batch_size_is_rejected() {
        let mut b = backend();
        assert!(b.eval(vec![0.0; 10], vec![8.0; 4], vec![8.0; 4]).is_err());
    }
}
