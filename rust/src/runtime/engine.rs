//! The evaluation-request engine: a dedicated service thread owning the PJRT
//! client, executables, and the current model parameters, fed through an
//! mpsc request queue.
//!
//! PJRT handles wrap raw pointers (`!Send`), so the actor pattern — one
//! owning thread, plain-`Vec<f32>` messages — is the sound way to serve
//! concurrent callers (RL episodes, benches, the CLI) without Python or
//! locks on the hot path.

use super::{f32_literal, f32_scalar, literal_to_f32, tensor_to_literal, Runtime};
use anyhow::{anyhow, bail, Context, Result};
use std::path::PathBuf;
use std::sync::mpsc;
use std::thread::JoinHandle;

enum Request {
    /// Quantized inference on one fixed-size batch: x is [B·in], bit vectors
    /// are per-layer. Replies with logits [B·classes].
    Eval {
        x: Vec<f32>,
        w_bits: Vec<f32>,
        a_bits: Vec<f32>,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    /// One quantization-aware finetuning step on [Bt·in]/[Bt·classes];
    /// updates the engine's parameters in place, replies with the loss.
    TrainStep {
        x: Vec<f32>,
        onehot: Vec<f32>,
        w_bits: Vec<f32>,
        a_bits: Vec<f32>,
        lr: f32,
        reply: mpsc::Sender<Result<f32>>,
    },
    /// Restore the pristine (base-trained) parameters.
    ResetParams { reply: mpsc::Sender<Result<()>> },
    /// Run the L1 crossbar demo artifact; replies (bit_exact, fast) outputs.
    Demo {
        x: Vec<f32>,
        w: Vec<f32>,
        w_bits: f32,
        a_bits: f32,
        reply: mpsc::Sender<Result<(Vec<f32>, Vec<f32>)>>,
    },
    Stop,
}

/// Handle to the engine service thread. Clone-able via `requester()`.
pub struct Engine {
    tx: mpsc::Sender<Request>,
    handle: Option<JoinHandle<()>>,
    pub num_layers: usize,
    pub eval_batch: usize,
    pub train_batch: usize,
    pub input_dim: usize,
    pub num_classes: usize,
    pub base_accuracy_f32: f64,
    pub demo_shape: (usize, usize, usize),
}

impl Engine {
    /// Start the service thread: builds the PJRT client, compiles the
    /// inference/train/demo artifacts, loads the trained parameters.
    pub fn start(artifacts_dir: PathBuf) -> Result<Engine> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<MetaInfo>>();

        let handle = std::thread::Builder::new()
            .name("lrmp-engine".to_string())
            .spawn(move || service(artifacts_dir, rx, ready_tx))
            .context("spawning engine thread")?;

        let meta = ready_rx
            .recv()
            .context("engine thread died during startup")??;
        Ok(Engine {
            tx,
            handle: Some(handle),
            num_layers: meta.num_layers,
            eval_batch: meta.eval_batch,
            train_batch: meta.train_batch,
            input_dim: meta.input_dim,
            num_classes: meta.num_classes,
            base_accuracy_f32: meta.base_accuracy_f32,
            demo_shape: meta.demo_shape,
        })
    }

    fn roundtrip<T>(
        &self,
        make: impl FnOnce(mpsc::Sender<Result<T>>) -> Request,
    ) -> Result<T> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(make(reply))
            .map_err(|_| anyhow!("engine thread gone"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    /// Quantized logits for one fixed-size batch.
    pub fn eval(&self, x: Vec<f32>, w_bits: Vec<f32>, a_bits: Vec<f32>) -> Result<Vec<f32>> {
        if x.len() != self.eval_batch * self.input_dim {
            bail!(
                "eval expects exactly {}x{} inputs, got {}",
                self.eval_batch,
                self.input_dim,
                x.len()
            );
        }
        self.roundtrip(|reply| Request::Eval {
            x,
            w_bits,
            a_bits,
            reply,
        })
    }

    /// One finetuning step; returns the batch loss.
    pub fn train_step(
        &self,
        x: Vec<f32>,
        onehot: Vec<f32>,
        w_bits: Vec<f32>,
        a_bits: Vec<f32>,
        lr: f32,
    ) -> Result<f32> {
        if x.len() != self.train_batch * self.input_dim {
            bail!(
                "train_step expects exactly {}x{} inputs, got {}",
                self.train_batch,
                self.input_dim,
                x.len()
            );
        }
        self.roundtrip(|reply| Request::TrainStep {
            x,
            onehot,
            w_bits,
            a_bits,
            lr,
            reply,
        })
    }

    pub fn reset_params(&self) -> Result<()> {
        self.roundtrip(|reply| Request::ResetParams { reply })
    }

    /// Run the crossbar-demo artifact (L1 bit-exact vs fast kernels).
    pub fn crossbar_demo(
        &self,
        x: Vec<f32>,
        w: Vec<f32>,
        w_bits: f32,
        a_bits: f32,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        self.roundtrip(|reply| Request::Demo {
            x,
            w,
            w_bits,
            a_bits,
            reply,
        })
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

struct MetaInfo {
    num_layers: usize,
    eval_batch: usize,
    train_batch: usize,
    input_dim: usize,
    num_classes: usize,
    base_accuracy_f32: f64,
    demo_shape: (usize, usize, usize),
}

/// The service loop (runs on the engine thread, owns all PJRT state).
fn service(
    artifacts_dir: PathBuf,
    rx: mpsc::Receiver<Request>,
    ready: mpsc::Sender<Result<MetaInfo>>,
) {
    let setup = (|| -> Result<_> {
        let rt = Runtime::new(&artifacts_dir)?;
        let infer = rt.compile_infer()?;
        let train = rt.compile_train_step()?;
        let demo = rt.compile_crossbar_demo()?;
        let pristine = rt.manifest.params()?;
        let params: Vec<xla::Literal> = pristine
            .iter()
            .map(tensor_to_literal)
            .collect::<Result<_>>()?;
        Ok((rt, infer, train, demo, pristine, params))
    })();

    let (rt, infer, train, demo, pristine, mut params) = match setup {
        Ok(v) => v,
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let m = &rt.manifest;
    let input_dim = m.layer_dims[0];
    let num_layers = m.num_layers;
    let _ = ready.send(Ok(MetaInfo {
        num_layers,
        eval_batch: m.eval_batch,
        train_batch: m.train_batch,
        input_dim,
        num_classes: m.num_classes,
        base_accuracy_f32: m.base_accuracy_f32,
        demo_shape: m.demo_shape,
    }));

    let bits_dims = [num_layers as i64];
    while let Ok(req) = rx.recv() {
        match req {
            Request::Stop => break,
            Request::ResetParams { reply } => {
                let r = pristine
                    .iter()
                    .map(tensor_to_literal)
                    .collect::<Result<Vec<_>>>()
                    .map(|p| params = p);
                let _ = reply.send(r);
            }
            Request::Eval {
                x,
                w_bits,
                a_bits,
                reply,
            } => {
                let r = (|| -> Result<Vec<f32>> {
                    let b = m.eval_batch as i64;
                    // ABI: x, params..., w_bits, a_bits. Parameters are
                    // passed by reference — no per-request weight copies.
                    let xl = f32_literal(&x, &[b, input_dim as i64])?;
                    let wl = f32_literal(&w_bits, &bits_dims)?;
                    let al = f32_literal(&a_bits, &bits_dims)?;
                    let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(3 + params.len());
                    inputs.push(&xl);
                    inputs.extend(params.iter());
                    inputs.push(&wl);
                    inputs.push(&al);
                    let out = infer.run(&inputs)?;
                    Ok(literal_to_f32(&out[0])?.1)
                })();
                let _ = reply.send(r);
            }
            Request::TrainStep {
                x,
                onehot,
                w_bits,
                a_bits,
                lr,
                reply,
            } => {
                let r = (|| -> Result<f32> {
                    let bt = m.train_batch as i64;
                    let xl = f32_literal(&x, &[bt, input_dim as i64])?;
                    let tl = f32_literal(&onehot, &[bt, m.num_classes as i64])?;
                    let wl = f32_literal(&w_bits, &bits_dims)?;
                    let al = f32_literal(&a_bits, &bits_dims)?;
                    let lrl = f32_scalar(lr);
                    let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(5 + params.len());
                    inputs.push(&xl);
                    inputs.push(&tl);
                    inputs.extend(params.iter());
                    inputs.push(&wl);
                    inputs.push(&al);
                    inputs.push(&lrl);
                    let mut out = train.run(&inputs)?;
                    // ABI: (params'..., loss).
                    let loss_lit = out.pop().expect("train_step returns loss");
                    let loss = loss_lit.to_vec::<f32>()?[0];
                    params = out;
                    Ok(loss)
                })();
                let _ = reply.send(r);
            }
            Request::Demo {
                x,
                w,
                w_bits,
                a_bits,
                reply,
            } => {
                let r = (|| -> Result<(Vec<f32>, Vec<f32>)> {
                    let (bd, rd, nd) = m.demo_shape;
                    let inputs = vec![
                        f32_literal(&x, &[bd as i64, rd as i64])?,
                        f32_literal(&w, &[rd as i64, nd as i64])?,
                        f32_scalar(w_bits),
                        f32_scalar(a_bits),
                    ];
                    let out = demo.run(&inputs)?;
                    Ok((literal_to_f32(&out[0])?.1, literal_to_f32(&out[1])?.1))
                })();
                let _ = reply.send(r);
            }
        }
    }
}

