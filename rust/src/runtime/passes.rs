//! Graph-rewrite pass pipeline: optimizations that run **between**
//! `graph::lower_nodes` and `Graph::compile`'s schedule/arena assignment.
//!
//! The pipeline operates on the raw `Vec<Node>` a lowering produced — it
//! never sees (or needs) a compiled schedule, and `Graph::compile`
//! re-validates everything afterwards, so a buggy pass can at worst turn
//! a compilable graph into a typed `GraphError`, never into silent
//! miscompilation of the structural invariants. Semantic preservation is
//! enforced one level up: `SimBackend` keeps the **unoptimized** graph as
//! its `eval_reference` comparator, and the test suite / bench / CI gate
//! every pass-enabled eval bitwise against it.
//!
//! # Production passes (pipeline order)
//!
//! 1. [`DeadNodeElim`] — removes nodes with no path to the `Output` node
//!    (auxiliary heads, unused producers). It runs **first** so a dead
//!    consumer can no longer block a fusion: a Pool whose second reader
//!    is dead is single-consumer once the corpse is gone.
//! 2. [`FuseConvPool`] — folds an `Op::Pool` into its producing
//!    `Op::Conv` (`pool: Some(factor)`), so the conv's scatter writes the
//!    pooled grid directly and the full-resolution CHW intermediate never
//!    exists (on VGG-style chains this roughly halves the conv-path slot
//!    arena). Legality (all must hold, checked per candidate):
//!    - the Pool's sole input is a Conv with `pool: None` (no re-fusing
//!      an already-fused conv),
//!    - the Conv's **only** consumer is that Pool (another reader needs
//!      the full-resolution grid),
//!    - the Pool itself has exactly **one** consumer (rewiring several
//!      readers would be semantically fine — they would all read the
//!      identical pooled tensor — but the conservative rule keeps the
//!      rewrite local and is what the legality tests pin),
//!    - the Pool carries no fused ReLU (the lowering never emits one),
//!    - the geometries agree (`channels == out_c`, `hw == out_hw`, factor
//!      divides the grid) — violations mean a malformed graph, which is
//!      left for `Graph::compile` to report instead of being papered
//!      over.
//!
//!    The fused node keeps the conv's ReLU flag: the executor applies
//!    ReLU per value *before* the max-accumulate, which is bitwise
//!    identical to the unfused ReLU-then-pool order (the scatter visits a
//!    pooled window's positions in exactly the `(dy, dx)` order
//!    `gemm::max_pool` reduces in).
//!
//! # Adding a pass
//!
//! Implement [`Pass`] (`run` mutates the node list and returns how many
//! rewrites it applied — 0 must mean "list untouched"), append it to
//! [`default_pipeline`] at the right position, and gate it with a
//! [`PassConfig`] field so the equivalence property tests can toggle it.
//! A pass that removes or merges nodes must renumber every `NodeId` via
//! [`compact`]; one that only annotates nodes in place needs no
//! renumbering. Every pass must be semantics-preserving **bitwise** — if
//! a rewrite changes any logit bit on any supported net, the
//! `passes-on-vs-off` property test and the bench's `passes_bit_exact`
//! gate fail.

use crate::runtime::graph::{Node, NodeId, Op};

/// Which passes [`run`] applies. `Default` enables the full production
/// pipeline; [`PassConfig::none`] compiles the lowering verbatim (the
/// comparator configuration the equivalence tests and the bench use).
#[derive(Clone, Copy, Debug)]
pub struct PassConfig {
    pub dead_node_elim: bool,
    pub fuse_conv_pool: bool,
}

impl Default for PassConfig {
    fn default() -> PassConfig {
        PassConfig {
            dead_node_elim: true,
            fuse_conv_pool: true,
        }
    }
}

impl PassConfig {
    /// Every pass disabled: the compiled graph is the lowering verbatim.
    pub fn none() -> PassConfig {
        PassConfig {
            dead_node_elim: false,
            fuse_conv_pool: false,
        }
    }
}

/// One pass's outcome within a [`PassReport`].
#[derive(Clone, Copy, Debug)]
pub struct PassStat {
    pub name: &'static str,
    /// Rewrites applied (nodes removed / ops fused); 0 = list untouched.
    pub rewrites: usize,
}

/// What the pipeline did to a node list (`inspect`/`serve` print it, the
/// bench records it).
#[derive(Clone, Debug, Default)]
pub struct PassReport {
    pub nodes_before: usize,
    pub nodes_after: usize,
    /// One entry per pass that ran, in pipeline order.
    pub stats: Vec<PassStat>,
}

impl PassReport {
    /// Total rewrites across every pass.
    pub fn rewrites(&self) -> usize {
        self.stats.iter().map(|s| s.rewrites).sum()
    }

    /// Rewrites applied by the pass named `name` (0 when it did not run).
    pub fn rewrites_of(&self, name: &str) -> usize {
        self.stats
            .iter()
            .find(|s| s.name == name)
            .map_or(0, |s| s.rewrites)
    }

    /// One-line rendering, e.g.
    /// `dead-node-elim x0, fuse-conv-pool x5 (24 -> 19 nodes)`.
    pub fn render(&self) -> String {
        if self.stats.is_empty() {
            return format!("no passes ({} nodes)", self.nodes_after);
        }
        let stats = self
            .stats
            .iter()
            .map(|s| format!("{} x{}", s.name, s.rewrites))
            .collect::<Vec<_>>()
            .join(", ");
        format!(
            "{stats} ({} -> {} nodes)",
            self.nodes_before, self.nodes_after
        )
    }
}

/// A graph-rewrite pass over the pre-compile node list (see module docs
/// for the contract).
pub trait Pass {
    fn name(&self) -> &'static str;
    /// Rewrite the node list in place; returns the number of rewrites
    /// applied (0 must mean the list is untouched).
    fn run(&self, nodes: &mut Vec<Node>) -> usize;
}

/// The production pipeline for a configuration, in execution order.
pub fn default_pipeline(cfg: &PassConfig) -> Vec<Box<dyn Pass>> {
    let mut pipeline: Vec<Box<dyn Pass>> = Vec::new();
    if cfg.dead_node_elim {
        pipeline.push(Box::new(DeadNodeElim));
    }
    if cfg.fuse_conv_pool {
        pipeline.push(Box::new(FuseConvPool));
    }
    pipeline
}

/// Run the configured pipeline over a node list and report what changed.
pub fn run(nodes: &mut Vec<Node>, cfg: &PassConfig) -> PassReport {
    let nodes_before = nodes.len();
    let stats = default_pipeline(cfg)
        .iter()
        .map(|pass| PassStat {
            name: pass.name(),
            rewrites: pass.run(nodes),
        })
        .collect();
    PassReport {
        nodes_before,
        nodes_after: nodes.len(),
        stats,
    }
}

// ----------------------------------------------------------------------
// Pass 1: dead-node elimination
// ----------------------------------------------------------------------

/// Removes every node with no path to an `Output` node: auxiliary heads,
/// unused producers, disconnected debris. `Input` and `Output` nodes are
/// always kept — they are structural anchors, and duplicate/missing
/// detection is `Graph::compile`'s job, which this pass must not mask.
pub struct DeadNodeElim;

impl Pass for DeadNodeElim {
    fn name(&self) -> &'static str {
        "dead-node-elim"
    }

    fn run(&self, nodes: &mut Vec<Node>) -> usize {
        let n = nodes.len();
        let mut keep = vec![false; n];
        let mut stack: Vec<usize> = Vec::new();
        for (i, nd) in nodes.iter().enumerate() {
            if matches!(nd.op, Op::Input { .. } | Op::Output) {
                keep[i] = true;
                stack.push(i);
            }
        }
        while let Some(i) = stack.pop() {
            for &NodeId(j) in &nodes[i].inputs {
                // Out-of-range ids are left for compile's DanglingInput.
                if j < n && !keep[j] {
                    keep[j] = true;
                    stack.push(j);
                }
            }
        }
        let removed = keep.iter().filter(|&&k| !k).count();
        if removed > 0 {
            compact(nodes, &keep);
        }
        removed
    }
}

// ----------------------------------------------------------------------
// Pass 2: Conv+Pool fusion
// ----------------------------------------------------------------------

/// Folds a max-pool into the conv that feeds it (legality rules in the
/// module docs). The Pool node disappears; its consumer re-reads the
/// fused conv, whose output features shrink from `out_c · out_hw²` to
/// `out_c · (out_hw/f)²` — the liveness pass then sizes the conv's arena
/// slot at the pooled footprint.
pub struct FuseConvPool;

impl Pass for FuseConvPool {
    fn name(&self) -> &'static str {
        "fuse-conv-pool"
    }

    fn run(&self, nodes: &mut Vec<Node>) -> usize {
        let n = nodes.len();
        let mut consumers = vec![0usize; n];
        for nd in nodes.iter() {
            for &NodeId(j) in &nd.inputs {
                if j < n {
                    consumers[j] += 1;
                }
            }
        }
        let mut keep = vec![true; n];
        let mut fused = 0usize;
        for p in 0..n {
            let Op::Pool {
                channels,
                hw,
                factor,
            } = nodes[p].op
            else {
                continue;
            };
            // Legality: see the module docs. Geometry violations are left
            // for Graph::compile to report, so they also veto the fuse.
            if nodes[p].relu || consumers[p] != 1 || nodes[p].inputs.len() != 1 {
                continue;
            }
            let NodeId(c) = nodes[p].inputs[0];
            if c >= n {
                continue;
            }
            let Op::Conv { layer, geom, pool } = nodes[c].op else {
                continue;
            };
            if pool.is_some() || consumers[c] != 1 {
                continue;
            }
            if geom.out_c != channels || geom.out_hw != hw || factor == 0 || hw % factor != 0 {
                continue;
            }
            // Rewrite: the conv absorbs the pool (keeping its own ReLU
            // flag), and the pool's consumer re-reads the conv.
            nodes[c].op = Op::Conv {
                layer,
                geom,
                pool: Some(factor),
            };
            for (i, nd) in nodes.iter_mut().enumerate() {
                if i == p {
                    continue;
                }
                for id in &mut nd.inputs {
                    if id.0 == p {
                        id.0 = c;
                    }
                }
            }
            keep[p] = false;
            fused += 1;
        }
        if fused > 0 {
            compact(nodes, &keep);
        }
        fused
    }
}

// ----------------------------------------------------------------------
// Shared machinery
// ----------------------------------------------------------------------

/// Drop the nodes whose `keep` flag is false and renumber every `NodeId`
/// for the new dense indexing. Callers guarantee no *kept* node
/// references a removed one; out-of-range ids (dangling inputs) pass
/// through untouched so `Graph::compile` still reports them.
fn compact(nodes: &mut Vec<Node>, keep: &[bool]) {
    let mut remap = vec![usize::MAX; nodes.len()];
    let mut next = 0usize;
    for (i, &k) in keep.iter().enumerate() {
        if k {
            remap[i] = next;
            next += 1;
        }
    }
    let old = std::mem::take(nodes);
    for (i, mut nd) in old.into_iter().enumerate() {
        if !keep[i] {
            continue;
        }
        for id in &mut nd.inputs {
            if id.0 < remap.len() {
                debug_assert_ne!(remap[id.0], usize::MAX, "kept node references a removed node");
                id.0 = remap[id.0];
            }
        }
        nodes.push(nd);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets;
    use crate::runtime::graph::{self, Graph};

    fn lower_with(net: &nets::Network, cfg: &PassConfig) -> (Graph, PassReport) {
        let mut nodes = graph::lower_nodes(net).unwrap();
        let report = run(&mut nodes, cfg);
        (Graph::compile(nodes).unwrap(), report)
    }

    #[test]
    fn disabled_pipeline_is_identity() {
        let mut nodes = graph::lower_nodes(&nets::conv_tiny()).unwrap();
        let before = nodes.len();
        let report = run(&mut nodes, &PassConfig::none());
        assert_eq!(nodes.len(), before);
        assert_eq!(report.rewrites(), 0);
        assert!(report.stats.is_empty());
    }

    #[test]
    fn conv_tiny_fuses_its_single_pool_and_shrinks_the_arena() {
        let unfused = graph::lower(&nets::conv_tiny()).unwrap();
        let (fused, report) = lower_with(&nets::conv_tiny(), &PassConfig::default());
        assert_eq!(unfused.pool_nodes(), 1);
        assert_eq!(fused.pool_nodes(), 0);
        assert_eq!(fused.fused_convs(), 1);
        assert_eq!(report.rewrites_of("fuse-conv-pool"), 1);
        assert_eq!(report.rewrites_of("dead-node-elim"), 0);
        assert_eq!(fused.num_nodes(), unfused.num_nodes() - 1);
        // conv2's slot now holds the pooled 8ch 4x4 grid, not 8x8.
        assert!(
            fused.arena_floats_per_sample() < unfused.arena_floats_per_sample(),
            "fusion must shrink the slot arena: {} vs {}",
            fused.arena_floats_per_sample(),
            unfused.arena_floats_per_sample()
        );
        // Logit geometry is untouched.
        assert_eq!(
            fused.out_features(fused.output()),
            unfused.out_features(unfused.output())
        );
    }

    #[test]
    fn vgg16_fuses_all_five_pools_and_cuts_the_arena_by_a_quarter_plus() {
        let unfused = graph::lower(&nets::vgg16()).unwrap();
        let (fused, report) = lower_with(&nets::vgg16(), &PassConfig::default());
        assert_eq!(unfused.pool_nodes(), 5);
        assert_eq!(fused.pool_nodes(), 0);
        assert_eq!(fused.fused_convs(), 5);
        assert_eq!(report.rewrites_of("fuse-conv-pool"), 5);
        let (before, after) = (
            unfused.arena_floats_per_sample(),
            fused.arena_floats_per_sample(),
        );
        // The 64ch 224x224 grid no longer needs a twin slot for conv2:
        // the fused arena is at most 3/4 of the unfused one (measured:
        // ~4.0M vs ~6.4M floats per sample).
        assert!(
            after * 4 <= before * 3,
            "vgg16 fusion must cut the slot arena by >= 25%: {before} -> {after}"
        );
    }

    #[test]
    fn resnet_tiny_global_pool_after_the_add_does_not_fuse() {
        // The only pool reads an Add node, not a Conv: nothing to fuse.
        let (fused, report) = lower_with(&nets::resnet::resnet_tiny(), &PassConfig::default());
        assert_eq!(fused.pool_nodes(), 1);
        assert_eq!(fused.fused_convs(), 0);
        assert_eq!(report.rewrites(), 0);
    }

    #[test]
    fn mlp_is_untouched_by_the_pipeline() {
        let unfused = graph::lower(&nets::mlp_tiny()).unwrap();
        let (fused, report) = lower_with(&nets::mlp_tiny(), &PassConfig::default());
        assert_eq!(report.rewrites(), 0);
        assert_eq!(fused.num_nodes(), unfused.num_nodes());
        assert_eq!(
            fused.arena_floats_per_sample(),
            unfused.arena_floats_per_sample()
        );
    }
}
