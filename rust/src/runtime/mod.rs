//! L3 ↔ L2 bridge: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compile them on the PJRT CPU client, and execute
//! them from the rust hot path. Python is never involved at runtime.
//!
//! Interchange is HLO *text* — xla_extension 0.5.1 rejects jax ≥ 0.5's
//! 64-bit-instruction-id protos; the text parser reassigns ids (see
//! /opt/xla-example/README.md and DESIGN.md §1).
//!
//! # The sim hot path
//!
//! When PJRT artifacts are absent, serving runs on the offline sim stack,
//! whose layering is the crate's performance-critical core (every search
//! episode and every offline `serve` request funnels through it):
//!
//! - [`graph`] — the graph IR: networks (sequential *and* residual)
//!   lower into a small dataflow graph (`Input`/`MatMul`/`Conv`/`Pool`/
//!   `Add`/`Output`), compiled into a topological schedule with
//!   buffer-liveness arena slots. `SimBackend::supports` is "does this
//!   network lower?" — no topology blacklist.
//! - [`passes`] — the graph-rewrite pass pipeline that runs between
//!   lowering and `Graph::compile`: dead-node elimination plus Conv+Pool
//!   fusion (a pool folds into its producing conv, which then scatters
//!   the pooled grid directly — the full-resolution CHW intermediate
//!   never exists). Every pass is semantics-preserving bitwise; the
//!   unoptimized graph stays alive as `SimBackend::eval_reference`'s
//!   comparator and CI gates on the equivalence.
//! - [`pool`] — a persistent worker-thread pool, created once per
//!   `SimBackend` and reused by every matmul of every eval. Workers park
//!   on a condvar between jobs and claim row-chunk tickets dynamically,
//!   so dispatch costs a wake-up instead of a `thread::scope` spawn.
//! - [`gemm`] — the quantized-matmul kernels over a column-panel packed
//!   weight layout: `matmul_naive` (reference), `matmul_blocked` (the
//!   PR 2 scope kernel, kept as comparator) and `matmul_pooled` (the hot
//!   path: register-tiled 4×16 microkernel fanned across the pool). All
//!   three agree bit for bit; CI gates on it.
//! - [`simnet`] — `SimBackend`, the deterministic quantized-forward
//!   backend executing the compiled schedule. Per-layer packed-weight
//!   caching (one layer's `w_bits` change repacks only that layer), a
//!   construction-time arena sized by the graph's liveness pass (skip
//!   tensors hold their own slots), and logits returned in the request's
//!   own buffer make steady-state eval allocation-free. Its
//!   `eval_reference` straight-line executor is the bitwise comparator
//!   the bench and CI gate on. `SimOptions::overlap` swaps the serial
//!   topo walk for a wavefront executor: independent branches dispatch
//!   in the same wave and `eval_pair` pipelines two evals one wave
//!   apart over the shared pool — bitwise identical to serial by
//!   contract (the bench's `overlap_bit_exact` gate).
//!
//! `cargo bench --bench bench_simnet` measures the stack and emits
//! `BENCH_simnet.json` (schema v8 in `rust/src/api/README.md` and
//! `docs/SCHEMAS.md`).

pub mod engine;
pub mod gemm;
pub mod graph;
pub mod passes;
pub mod pool;
pub mod simnet;

use crate::util::io::Tensor;
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub layer_dims: Vec<usize>,
    pub num_layers: usize,
    pub eval_batch: usize,
    pub train_batch: usize,
    pub num_classes: usize,
    pub base_accuracy_f32: f64,
    pub demo_shape: (usize, usize, usize),
    pub param_files: Vec<String>,
    pub dataset: DatasetFiles,
    pub exe_infer: String,
    pub exe_train_step: String,
    pub exe_crossbar_demo: String,
}

#[derive(Clone, Debug)]
pub struct DatasetFiles {
    pub x_train: String,
    pub y_train: String,
    pub x_test: String,
    pub y_test: String,
    pub n_train: usize,
    pub n_test: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let need_usize = |k: &str| -> Result<usize> {
            j.get(k)
                .as_usize()
                .with_context(|| format!("manifest missing numeric field '{k}'"))
        };
        let exes = j.get("executables");
        let need_exe = |k: &str| -> Result<String> {
            exes.get(k)
                .as_str()
                .map(str::to_string)
                .with_context(|| format!("manifest missing executables.{k}"))
        };
        let ds = j.get("dataset");
        let need_ds = |k: &str| -> Result<String> {
            ds.get(k)
                .as_str()
                .map(str::to_string)
                .with_context(|| format!("manifest missing dataset.{k}"))
        };
        let demo: Vec<usize> = j
            .get("demo_shape")
            .as_arr()
            .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
            .unwrap_or_default();
        if demo.len() != 3 {
            bail!("manifest demo_shape must have 3 entries");
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            layer_dims: j
                .get("layer_dims")
                .as_arr()
                .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
                .unwrap_or_default(),
            num_layers: need_usize("num_layers")?,
            eval_batch: need_usize("eval_batch")?,
            train_batch: need_usize("train_batch")?,
            num_classes: need_usize("num_classes")?,
            base_accuracy_f32: j.get("base_accuracy_f32").as_f64().unwrap_or(0.0),
            demo_shape: (demo[0], demo[1], demo[2]),
            param_files: j
                .get("params")
                .as_arr()
                .map(|a| {
                    a.iter()
                        .filter_map(|p| p.get("file").as_str().map(str::to_string))
                        .collect()
                })
                .unwrap_or_default(),
            dataset: DatasetFiles {
                x_train: need_ds("x_train")?,
                y_train: need_ds("y_train")?,
                x_test: need_ds("x_test")?,
                y_test: need_ds("y_test")?,
                n_train: ds.get("n_train").as_usize().unwrap_or(0),
                n_test: ds.get("n_test").as_usize().unwrap_or(0),
            },
            exe_infer: need_exe("infer")?,
            exe_train_step: need_exe("train_step")?,
            exe_crossbar_demo: need_exe("crossbar_demo")?,
        })
    }

    pub fn tensor(&self, file: &str) -> Result<Tensor> {
        Tensor::load(&self.dir.join(file))
    }

    /// Load the trained model parameters [w1, b1, w2, b2, ...].
    pub fn params(&self) -> Result<Vec<Tensor>> {
        self.param_files.iter().map(|f| self.tensor(f)).collect()
    }
}

/// Convert a host tensor into an XLA literal.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
    let lit = match (t.as_f32(), t.as_i32()) {
        (Some(v), _) => xla::Literal::vec1(v).reshape(&dims)?,
        (_, Some(v)) => xla::Literal::vec1(v).reshape(&dims)?,
        _ => bail!("unsupported tensor dtype for literal conversion"),
    };
    Ok(lit)
}

/// Build an f32 literal from a slice + dims.
pub fn f32_literal(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build a rank-0 f32 literal.
pub fn f32_scalar(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Read an f32 literal back into (dims, data).
pub fn literal_to_f32(lit: &xla::Literal) -> Result<(Vec<usize>, Vec<f32>)> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Ok((dims, data))
}

/// A compiled executable with its artifact identity.
pub struct Executable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute on literal inputs (owned or borrowed); flattens the jax
    /// `return_tuple=True` top-level tuple into its elements.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        inputs: &[L],
    ) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<L>(inputs)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        Ok(lit.to_tuple()?)
    }
}

/// The PJRT runtime: one CPU client, compile-on-demand artifacts.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { manifest, client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + parse + compile one HLO-text artifact.
    pub fn compile(&self, file: &str) -> Result<Executable> {
        let path = self.manifest.dir.join(file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {file}"))?;
        Ok(Executable {
            name: file.to_string(),
            exe,
        })
    }

    pub fn compile_infer(&self) -> Result<Executable> {
        let f = self.manifest.exe_infer.clone();
        self.compile(&f)
    }
    pub fn compile_train_step(&self) -> Result<Executable> {
        let f = self.manifest.exe_train_step.clone();
        self.compile(&f)
    }
    pub fn compile_crossbar_demo(&self) -> Result<Executable> {
        let f = self.manifest.exe_crossbar_demo.clone();
        self.compile(&f)
    }
}

/// Default artifacts directory: `$LRMP_ARTIFACTS` or `<repo>/artifacts`.
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("LRMP_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parse_rejects_missing_fields() {
        let dir = std::env::temp_dir().join("lrmp-manifest-test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{}").unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn manifest_error_mentions_make_artifacts() {
        let dir = std::env::temp_dir().join("lrmp-manifest-missing");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let err = Manifest::load(&dir).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }

    #[test]
    fn default_dir_points_at_repo_artifacts() {
        let d = default_artifacts_dir();
        assert!(d.ends_with("artifacts") || std::env::var("LRMP_ARTIFACTS").is_ok());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let lit = tensor_to_literal(&t).unwrap();
        let (dims, data) = literal_to_f32(&lit).unwrap();
        assert_eq!(dims, vec![2, 3]);
        assert_eq!(data, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }
}
