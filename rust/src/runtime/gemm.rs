//! Quantized-matmul kernels shared by the FC and conv (im2col) paths of the
//! offline sim backend.
//!
//! Three kernels compute `out[m×n] = x[m×k] · w[k×n]`:
//!
//! - [`matmul_naive`]: the reference triple loop (the historical
//!   `SimBackend` hot path) — axpy over the output row, inputs equal to
//!   exactly zero skipped.
//! - [`matmul_blocked`]: the PR 2 kernel — cache-blocked over a
//!   column-panel *packed* weight layout ([`PackedMat`]), one scalar
//!   accumulator row, split across fresh `thread::scope` workers by batch
//!   rows for large shapes. Kept as the bench comparator for the pooled
//!   kernel (its per-call spawn/join is exactly the overhead the pool
//!   removes).
//! - [`matmul_pooled`]: the serving hot-path kernel — the same packed
//!   layout driven through a register-tiled microkernel
//!   ([`TILE_ROWS`]`×`[`TILE_COLS`] accumulator tiles whose fixed-size
//!   inner loops autovectorize on stable Rust) and fanned out over a
//!   persistent [`WorkerPool`](crate::runtime::pool::WorkerPool) instead
//!   of per-call thread spawns.
//!
//! All kernels accumulate every output element over the reduction index in
//! the same ascending order, so their results agree **bit for bit**
//! (floating-point addition is not associative, but no kernel ever
//! reassociates: blocking only changes *when* a partial sum is resumed,
//! never the order of its terms). The naive kernel skips inputs equal to
//! exactly zero while the tiled microkernel adds them branchlessly; both
//! are bitwise no-ops because `acc + ±0.0 == acc` for every value the
//! kernels can produce — a running sum that starts at +0.0 can never
//! become -0.0 (IEEE 754: `a + b == -0.0` only when both addends are
//! -0.0). The bench harness and CI smoke job exploit this: any divergence
//! between the kernels is a hard failure, not a tolerance judgement.
//! Inputs are assumed finite (synthetic quantized weights and activations
//! always are).
//!
//! The module also hosts the conv lowering helpers: [`im2col_chunk`]
//! (patch-matrix construction, chunked so the scratch buffer stays
//! cache-sized even for 224×224 inputs), the **patch-streaming** conv
//! entry point [`conv_rows_streamed`] — the serving hot path packs im2col
//! rows [`TILE_ROWS`] at a time straight into a tile-height panel and
//! feeds the microkernel from it, so the `m × patch_len` patch matrix is
//! never materialized — and the direct-convolution reference
//! [`conv2d_ref`] used by the tests. All are written with the same
//! reduction order, so every path matches the others bit for bit.
//!
//! A third precision tier lives alongside the f32 kernels: the
//! **packed-integer** family ([`PackedMatI8`], [`matmul_pooled_i8`],
//! [`conv_rows_streamed_i8`]) executes layers whose quantized operands are
//! small integer codes under power-of-two scales. Its i32 accumulators are
//! exact, and because eligible layers satisfy the
//! `k · (2^w−1)(2^a−1) < 2^24` predicate (`quant::Policy::int_exact`),
//! every f32 partial sum of the corresponding f32-kernel run is exact too —
//! so the integer path is **bitwise identical** to the f32 kernels by
//! construction, not by tolerance. See the "integer tier" section below.

use crate::runtime::pool::{self, WorkerPool};

/// Column-panel width of the packed weight layout, in f32 lanes.
pub const PANEL_COLS: usize = 64;
/// Reduction-dimension block: rows of a panel processed per pass while the
/// panel block (`BLOCK_ROWS × PANEL_COLS × 4` bytes = 16 KiB) stays L1-hot.
pub const BLOCK_ROWS: usize = 64;
/// Microkernel register-tile height: batch rows whose accumulators live in
/// registers together, so each streamed weight row is reused this many
/// times per load.
pub const TILE_ROWS: usize = 4;
/// Microkernel register-tile width in f32 lanes (two 8-lane vectors); the
/// fixed-size inner loops over this width autovectorize on stable Rust.
pub const TILE_COLS: usize = 16;
/// Below this many flops (2·m·k·n) the scope kernel stays single-threaded:
/// thread-spawn overhead would dominate.
const MT_MIN_FLOPS: usize = 1 << 24;
/// Multithreading threshold of the pooled kernel. Waking parked workers
/// costs microseconds instead of the scope kernel's spawn/join, so the
/// pool pays off on much smaller shapes.
const POOL_MIN_FLOPS: usize = 1 << 21;

/// A weight matrix packed into column panels: panel `p` holds columns
/// `[p·PANEL_COLS, min((p+1)·PANEL_COLS, cols))`, stored row-major within
/// the panel. Successive reduction rows of a panel are contiguous, so the
/// blocked kernel streams weights linearly instead of striding by `cols`.
#[derive(Clone, Debug)]
pub struct PackedMat {
    /// Reduction dimension (input features / lowered rows).
    pub rows: usize,
    /// Output dimension (output features / lowered cols).
    pub cols: usize,
    data: Vec<f32>,
}

impl PackedMat {
    /// Pack a row-major `rows × cols` matrix into column panels.
    pub fn pack(w: &[f32], rows: usize, cols: usize) -> PackedMat {
        assert_eq!(w.len(), rows * cols, "weight buffer must be rows*cols");
        let mut data = vec![0f32; rows * cols];
        let mut off = 0;
        let mut j0 = 0;
        while j0 < cols {
            let pw = PANEL_COLS.min(cols - j0);
            for i in 0..rows {
                data[off..off + pw].copy_from_slice(&w[i * cols + j0..i * cols + j0 + pw]);
                off += pw;
            }
            j0 += pw;
        }
        PackedMat { rows, cols, data }
    }

    /// Unpack back to the row-major layout (tests / debugging).
    pub fn unpack(&self) -> Vec<f32> {
        let (rows, cols) = (self.rows, self.cols);
        let mut w = vec![0f32; rows * cols];
        let mut off = 0;
        let mut j0 = 0;
        while j0 < cols {
            let pw = PANEL_COLS.min(cols - j0);
            for i in 0..rows {
                w[i * cols + j0..i * cols + j0 + pw].copy_from_slice(&self.data[off..off + pw]);
                off += pw;
            }
            j0 += pw;
        }
        w
    }
}

/// Reference kernel: `out[m×n] = x[m×k] · w[k×n]` with `w` row-major.
/// Inputs equal to exactly 0.0 are skipped (post-ReLU activations are
/// sparse); adding their ±0.0 products would be a bitwise no-op anyway.
pub fn matmul_naive(x: &[f32], w: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(x.len(), m * k, "x must be m*k");
    assert_eq!(w.len(), k * n, "w must be k*n");
    assert_eq!(out.len(), m * n, "out must be m*n");
    out.fill(0.0);
    for row in 0..m {
        let xin = &x[row * k..(row + 1) * k];
        let yout = &mut out[row * n..(row + 1) * n];
        for (i, &xi) in xin.iter().enumerate() {
            if xi == 0.0 {
                continue;
            }
            let wrow = &w[i * n..(i + 1) * n];
            for (yj, &wj) in yout.iter_mut().zip(wrow) {
                *yj += xi * wj;
            }
        }
    }
}

/// Blocked kernel: `out[m×n] = x[m×k] · w` over the packed layout, with the
/// thread count chosen automatically (`LRMP_SIM_THREADS` overrides).
/// Bit-for-bit identical to [`matmul_naive`] (see module docs).
pub fn matmul_blocked(x: &[f32], w: &PackedMat, m: usize, out: &mut [f32]) {
    let flops = 2usize
        .saturating_mul(m)
        .saturating_mul(w.rows)
        .saturating_mul(w.cols);
    let threads = if flops < MT_MIN_FLOPS {
        1
    } else {
        pool::default_threads().min(m)
    };
    matmul_blocked_threads(x, w, m, threads.max(1), out);
}

/// [`matmul_blocked`] with an explicit worker count (1 = fully sequential).
/// The thread split is by batch rows, so every output element is still
/// computed by exactly one worker in the canonical reduction order —
/// results are identical for every `threads` value.
pub fn matmul_blocked_threads(
    x: &[f32],
    w: &PackedMat,
    m: usize,
    threads: usize,
    out: &mut [f32],
) {
    let (k, n) = (w.rows, w.cols);
    assert_eq!(x.len(), m * k, "x must be m*k");
    assert_eq!(out.len(), m * n, "out must be m*n");
    out.fill(0.0);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let threads = threads.clamp(1, m);
    if threads == 1 {
        gemm_task(x, m, k, n, &w.data, out);
        return;
    }
    let rows_per = (m + threads - 1) / threads;
    let data = w.data.as_slice();
    std::thread::scope(|s| {
        for (xc, oc) in x.chunks(rows_per * k).zip(out.chunks_mut(rows_per * n)) {
            let rows = oc.len() / n;
            s.spawn(move || gemm_task(xc, rows, k, n, data, oc));
        }
    });
}

/// Compute `out[rows×n] = x[rows×k] · packed` for one worker's row chunk.
/// `out` must be zeroed. Loop nest: column panel → reduction block → row,
/// so a 16 KiB panel block is reused across every row while L1-hot, and the
/// per-(row, panel) accumulator lives in registers across the block.
fn gemm_task(x: &[f32], rows: usize, k: usize, n: usize, data: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(out.len(), rows * n);
    let mut acc = [0f32; PANEL_COLS];
    let mut j0 = 0;
    let mut poff = 0;
    while j0 < n {
        let pw = PANEL_COLS.min(n - j0);
        let panel = &data[poff..poff + k * pw];
        let mut i0 = 0;
        while i0 < k {
            let ib = BLOCK_ROWS.min(k - i0);
            for row in 0..rows {
                let xrow = &x[row * k + i0..row * k + i0 + ib];
                let orow = &mut out[row * n + j0..row * n + j0 + pw];
                let acc = &mut acc[..pw];
                acc.copy_from_slice(orow);
                for (di, &xi) in xrow.iter().enumerate() {
                    if xi == 0.0 {
                        continue;
                    }
                    let wrow = &panel[(i0 + di) * pw..(i0 + di + 1) * pw];
                    for (a, &wv) in acc.iter_mut().zip(wrow) {
                        *a += xi * wv;
                    }
                }
                orow.copy_from_slice(acc);
            }
            i0 += ib;
        }
        j0 += pw;
        poff += k * pw;
    }
}

/// The worker count [`matmul_blocked`] and default-built pools use for
/// large shapes (`LRMP_SIM_THREADS` override honored) — exposed for bench
/// reporting.
pub fn worker_threads() -> usize {
    pool::default_threads()
}

// ----------------------------------------------------------------------
// Pooled, register-tiled kernel (the serving hot path)
// ----------------------------------------------------------------------

/// Output base pointer smuggled into a pool closure; every part writes a
/// disjoint range, so sharing the pointer across workers is sound. Also
/// used by `runtime::simnet`'s parallel-over-samples conv path.
pub(crate) struct SendPtr(pub(crate) *mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Pooled kernel: `out[m×n] = x[m×k] · w` over the packed layout through
/// the register-tiled microkernel, fanned out across `pool` for large
/// shapes (small ones run inline — waking workers costs more than the
/// matmul). Bit-for-bit identical to [`matmul_naive`] (see module docs).
pub fn matmul_pooled(x: &[f32], w: &PackedMat, m: usize, pool: &WorkerPool, out: &mut [f32]) {
    let flops = 2usize
        .saturating_mul(m)
        .saturating_mul(w.rows)
        .saturating_mul(w.cols);
    let threads = if flops < POOL_MIN_FLOPS {
        1
    } else {
        pool.threads().min(m)
    };
    matmul_pooled_threads(x, w, m, pool, threads.max(1), out);
}

/// [`matmul_pooled`] with an explicit worker count (1 = fully inline on
/// the calling thread). The split is by batch rows in [`TILE_ROWS`]
/// multiples and every output element is computed by exactly one part in
/// the canonical reduction order — results are identical for every
/// `threads` value and equal to the other kernels bit for bit.
pub fn matmul_pooled_threads(
    x: &[f32],
    w: &PackedMat,
    m: usize,
    pool: &WorkerPool,
    threads: usize,
    out: &mut [f32],
) {
    let (k, n) = (w.rows, w.cols);
    assert_eq!(x.len(), m * k, "x must be m*k");
    assert_eq!(out.len(), m * n, "out must be m*n");
    out.fill(0.0);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let threads = threads.clamp(1, m);
    let data = w.data.as_slice();
    if threads == 1 {
        gemm_chunk_tiled(x, m, k, n, data, out);
        return;
    }
    // ~2 parts per thread so a worker that finishes early steals another
    // chunk; chunks are TILE_ROWS multiples to keep full register tiles.
    let target = threads * 2;
    let mut rows_per = (m + target - 1) / target;
    rows_per = ((rows_per + TILE_ROWS - 1) / TILE_ROWS) * TILE_ROWS;
    let parts = (m + rows_per - 1) / rows_per;
    let optr = SendPtr(out.as_mut_ptr());
    pool.run(parts, |p| {
        let r0 = p * rows_per;
        let rows = rows_per.min(m - r0);
        let xs = &x[r0 * k..(r0 + rows) * k];
        // SAFETY: part `p` owns rows [r0, r0 + rows) of `out` exclusively
        // (parts tile the row range without overlap), and `out` outlives
        // `pool.run`, which blocks until every part has finished.
        let os = unsafe { std::slice::from_raw_parts_mut(optr.0.add(r0 * n), rows * n) };
        gemm_chunk_tiled(xs, rows, k, n, data, os);
    });
}

/// Register-tiled microkernel over one chunk of batch rows; `out` must be
/// zeroed. Loop nest: column panel → reduction block → TILE_COLS column
/// slice → TILE_ROWS row tile, so a 4 KiB weight slice stays L1-hot while
/// every full tile keeps a TILE_ROWS×TILE_COLS accumulator in registers
/// and reuses each streamed weight row TILE_ROWS times.
fn gemm_chunk_tiled(x: &[f32], rows: usize, k: usize, n: usize, data: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(out.len(), rows * n);
    let mut j0 = 0;
    let mut poff = 0;
    while j0 < n {
        let pw = PANEL_COLS.min(n - j0);
        let panel = &data[poff..poff + k * pw];
        let mut i0 = 0;
        while i0 < k {
            let ib = BLOCK_ROWS.min(k - i0);
            let mut jc = 0;
            while jc < pw {
                let nc = TILE_COLS.min(pw - jc);
                let mut r0 = 0;
                if nc == TILE_COLS {
                    while r0 + TILE_ROWS <= rows {
                        tile_mxn::<TILE_COLS>(x, k, r0, i0, ib, panel, pw, jc, out, n, j0);
                        r0 += TILE_ROWS;
                    }
                } else if nc == 8 {
                    while r0 + TILE_ROWS <= rows {
                        tile_mxn::<8>(x, k, r0, i0, ib, panel, pw, jc, out, n, j0);
                        r0 += TILE_ROWS;
                    }
                }
                while r0 < rows {
                    tile_edge_row(x, k, r0, i0, ib, panel, pw, jc, nc, out, n, j0);
                    r0 += 1;
                }
                jc += nc;
            }
            i0 += ib;
        }
        j0 += pw;
        poff += k * pw;
    }
}

/// One full TILE_ROWS×NC register tile: resume the partial sums from
/// `out`, stream `ib` weight rows through them, store back. `NC` is a
/// compile-time constant (16 or 8) so the inner loops fully unroll into
/// broadcast + mul + add vector bodies. Zero inputs are *not* skipped —
/// adding `xi·w` with `xi == ±0.0` is a bitwise no-op (see module docs),
/// and branchless bodies vectorize.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn tile_mxn<const NC: usize>(
    x: &[f32],
    k: usize,
    r0: usize,
    i0: usize,
    ib: usize,
    panel: &[f32],
    pw: usize,
    jc: usize,
    out: &mut [f32],
    n: usize,
    j0: usize,
) {
    let mut acc = [[0f32; NC]; TILE_ROWS];
    for (r, a) in acc.iter_mut().enumerate() {
        let base = (r0 + r) * n + j0 + jc;
        a.copy_from_slice(&out[base..base + NC]);
    }
    for di in 0..ib {
        let wbase = (i0 + di) * pw + jc;
        let wrow = &panel[wbase..wbase + NC];
        for (r, a) in acc.iter_mut().enumerate() {
            let xi = x[(r0 + r) * k + i0 + di];
            for (av, &wv) in a.iter_mut().zip(wrow) {
                *av += xi * wv;
            }
        }
    }
    for (r, a) in acc.iter().enumerate() {
        let base = (r0 + r) * n + j0 + jc;
        out[base..base + NC].copy_from_slice(a);
    }
}

/// Scalar edge path for leftover rows and odd column-slice widths; same
/// ascending reduction order as the tiles (skipping exact zeros, which is
/// bitwise equivalent — see module docs).
#[allow(clippy::too_many_arguments)]
fn tile_edge_row(
    x: &[f32],
    k: usize,
    row: usize,
    i0: usize,
    ib: usize,
    panel: &[f32],
    pw: usize,
    jc: usize,
    nc: usize,
    out: &mut [f32],
    n: usize,
    j0: usize,
) {
    let base = row * n + j0 + jc;
    for di in 0..ib {
        let xi = x[row * k + i0 + di];
        if xi == 0.0 {
            continue;
        }
        let wbase = (i0 + di) * pw + jc;
        let wrow = &panel[wbase..wbase + nc];
        for (o, &wv) in out[base..base + nc].iter_mut().zip(wrow) {
            *o += xi * wv;
        }
    }
}

// ----------------------------------------------------------------------
// Conv lowering (im2col) helpers
// ----------------------------------------------------------------------

/// Geometry of one 2-D convolution lowering (square input, H = W).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvGeom {
    pub in_c: usize,
    pub out_c: usize,
    pub kernel: usize,
    pub stride: usize,
    pub padding: usize,
    pub in_hw: usize,
    pub out_hw: usize,
}

impl ConvGeom {
    /// Lowered patch length R = K²·C — rows of the lowered weight matrix,
    /// ordered channel-major: r = (c·K + ky)·K + kx.
    pub fn patch_len(&self) -> usize {
        self.in_c * self.kernel * self.kernel
    }

    /// Input feature count C·H·W of one CHW sample.
    pub fn in_features(&self) -> usize {
        self.in_c * self.in_hw * self.in_hw
    }

    /// Output positions W² of one sample.
    pub fn num_positions(&self) -> usize {
        self.out_hw * self.out_hw
    }
}

/// Build im2col patch rows for output positions `[pos0, pos0 + npos)` of
/// one CHW sample `x` into `patches` (`npos × patch_len`, row-major).
/// Positions are row-major over the output grid (pos = oy·out_hw + ox);
/// out-of-bounds taps read the zero padding.
pub fn im2col_chunk(x: &[f32], g: &ConvGeom, pos0: usize, npos: usize, patches: &mut [f32]) {
    let pl = g.patch_len();
    assert_eq!(x.len(), g.in_features(), "sample must be in_c*in_hw^2");
    assert_eq!(patches.len(), npos * pl, "patch buffer must be npos*patch_len");
    assert!(pos0 + npos <= g.num_positions(), "positions out of range");
    for p in 0..npos {
        let pos = pos0 + p;
        let (oy, ox) = (pos / g.out_hw, pos % g.out_hw);
        let dst = &mut patches[p * pl..(p + 1) * pl];
        let mut d = 0;
        for c in 0..g.in_c {
            let plane = &x[c * g.in_hw * g.in_hw..(c + 1) * g.in_hw * g.in_hw];
            for ky in 0..g.kernel {
                let iy = (oy * g.stride + ky) as isize - g.padding as isize;
                let in_row = iy >= 0 && (iy as usize) < g.in_hw;
                for kx in 0..g.kernel {
                    let ix = (ox * g.stride + kx) as isize - g.padding as isize;
                    dst[d] = if in_row && ix >= 0 && (ix as usize) < g.in_hw {
                        plane[iy as usize * g.in_hw + ix as usize]
                    } else {
                        0.0
                    };
                    d += 1;
                }
            }
        }
    }
}

/// Patch-streaming conv rows: `prod[m × w.cols] = P · w`, where `P` is
/// the im2col patch matrix of output positions `[pos0, pos0 + m)` of one
/// CHW sample — computed **without materializing P**. Patch rows are
/// packed [`TILE_ROWS`] at a time into a tile-height panel of `strips`
/// and pushed straight through the register-tiled microkernel, so the
/// im2col scratch is `parts × TILE_ROWS × patch_len` floats total instead
/// of an `m × patch_len` buffer (32× smaller at the serving path's
/// 128-position chunks). Rows are split across up to `threads` pool parts
/// in `TILE_ROWS` multiples and part `p` packs into strip panel `p`, so
/// `strips` must hold at least `min(threads, ceil(m / TILE_ROWS)) ×
/// TILE_ROWS × patch_len` floats.
///
/// Every output element is computed by exactly one part in the canonical
/// ascending reduction order — the strip split never reorders any
/// element's terms — so the result is **bit for bit** equal to
/// [`im2col_chunk`] + [`matmul_naive`] over the materialized patch matrix
/// for every `threads` value (the tests and the bench gate on it).
#[allow(clippy::too_many_arguments)]
pub fn conv_rows_streamed(
    xs: &[f32],
    g: &ConvGeom,
    pos0: usize,
    m: usize,
    w: &PackedMat,
    pool: &WorkerPool,
    threads: usize,
    strips: &mut [f32],
    prod: &mut [f32],
) {
    let (k, n) = (w.rows, w.cols);
    let pl = g.patch_len();
    assert_eq!(k, pl, "packed conv weights must have patch_len rows");
    assert_eq!(prod.len(), m * n, "prod must be m*cols");
    assert!(pos0 + m <= g.num_positions(), "positions out of range");
    if m == 0 || n == 0 {
        return;
    }
    let threads = threads.clamp(1, m);
    let tiles = (m + TILE_ROWS - 1) / TILE_ROWS;
    let parts = threads.min(tiles);
    let spl = TILE_ROWS * pl;
    assert!(strips.len() >= parts * spl, "strip scratch too small");
    if parts == 1 {
        conv_rows_task(xs, g, pos0, m, w, &mut strips[..spl], prod);
        return;
    }
    // Contiguous row ranges in TILE_ROWS multiples; part p owns strip
    // panel p, so the part count never exceeds the panel count.
    let tiles_per = (tiles + parts - 1) / parts;
    let rows_per = tiles_per * TILE_ROWS;
    let nparts = (m + rows_per - 1) / rows_per;
    let sptr = SendPtr(strips.as_mut_ptr());
    let pptr = SendPtr(prod.as_mut_ptr());
    pool.run(nparts, |p| {
        let r0 = p * rows_per;
        let rows = rows_per.min(m - r0);
        // SAFETY: part `p` exclusively owns strip panel `p` and prod rows
        // [r0, r0 + rows) — parts tile both without overlap — and both
        // buffers outlive `pool.run`, which blocks until every part has
        // finished.
        let strip = unsafe { std::slice::from_raw_parts_mut(sptr.0.add(p * spl), spl) };
        let pr = unsafe { std::slice::from_raw_parts_mut(pptr.0.add(r0 * n), rows * n) };
        conv_rows_task(xs, g, pos0 + r0, rows, w, strip, pr);
    });
}

/// [`conv_rows_streamed`] with the worker count chosen from the chunk's
/// flops (the same [`POOL_MIN_FLOPS`](matmul_pooled) threshold the pooled
/// matmul uses: waking parked workers only pays off past it).
pub fn conv_rows_streamed_auto(
    xs: &[f32],
    g: &ConvGeom,
    pos0: usize,
    m: usize,
    w: &PackedMat,
    pool: &WorkerPool,
    strips: &mut [f32],
    prod: &mut [f32],
) {
    let flops = 2usize
        .saturating_mul(m)
        .saturating_mul(w.rows)
        .saturating_mul(w.cols);
    let threads = if flops < POOL_MIN_FLOPS {
        1
    } else {
        pool.threads()
    };
    conv_rows_streamed(xs, g, pos0, m, w, pool, threads.max(1), strips, prod);
}

/// One part's strip loop: pack `TILE_ROWS` patch rows into the panel, run
/// the tiled microkernel on them, advance. `strip` is one
/// `TILE_ROWS × patch_len` panel; `prod` covers exactly this part's
/// `m × cols` rows and is zeroed here (the microkernel resumes from it).
fn conv_rows_task(
    xs: &[f32],
    g: &ConvGeom,
    pos0: usize,
    m: usize,
    w: &PackedMat,
    strip: &mut [f32],
    prod: &mut [f32],
) {
    let (k, n) = (w.rows, w.cols);
    let pl = g.patch_len();
    prod.fill(0.0);
    let mut r0 = 0;
    while r0 < m {
        let h = TILE_ROWS.min(m - r0);
        im2col_chunk(xs, g, pos0 + r0, h, &mut strip[..h * pl]);
        gemm_chunk_tiled(
            &strip[..h * pl],
            h,
            k,
            n,
            &w.data,
            &mut prod[r0 * n..(r0 + h) * n],
        );
        r0 += h;
    }
}

/// Direct-convolution reference (tests only): `x` is one CHW sample, `w`
/// the row-major lowered `patch_len × out_c` weight matrix, `out` the CHW
/// `out_c × out_hw²` result. The reduction runs in the same channel-major
/// tap order as [`im2col_chunk`] + [`matmul_naive`] with the same
/// skip-exact-zero rule, so the two paths agree bit for bit.
pub fn conv2d_ref(x: &[f32], w: &[f32], g: &ConvGeom, out: &mut [f32]) {
    let pl = g.patch_len();
    assert_eq!(x.len(), g.in_features(), "sample must be in_c*in_hw^2");
    assert_eq!(w.len(), pl * g.out_c, "w must be patch_len*out_c");
    assert_eq!(out.len(), g.out_c * g.num_positions(), "out must be out_c*out_hw^2");
    for oc in 0..g.out_c {
        for oy in 0..g.out_hw {
            for ox in 0..g.out_hw {
                let mut acc = 0f32;
                let mut r = 0;
                for c in 0..g.in_c {
                    let plane = &x[c * g.in_hw * g.in_hw..(c + 1) * g.in_hw * g.in_hw];
                    for ky in 0..g.kernel {
                        let iy = (oy * g.stride + ky) as isize - g.padding as isize;
                        let in_row = iy >= 0 && (iy as usize) < g.in_hw;
                        for kx in 0..g.kernel {
                            let ix = (ox * g.stride + kx) as isize - g.padding as isize;
                            if in_row && ix >= 0 && (ix as usize) < g.in_hw {
                                let v = plane[iy as usize * g.in_hw + ix as usize];
                                if v != 0.0 {
                                    acc += v * w[r * g.out_c + oc];
                                }
                            }
                            r += 1;
                        }
                    }
                }
                out[(oc * g.out_hw + oy) * g.out_hw + ox] = acc;
            }
        }
    }
}

/// Channel-wise `f × f` max pooling with stride `f` over a CHW sample
/// (`hw` divisible by `f`); writes the pooled CHW sample into `out`.
pub fn max_pool(x: &[f32], channels: usize, hw: usize, f: usize, out: &mut [f32]) {
    assert!(f >= 1 && hw % f == 0, "pool factor must divide the grid");
    let o = hw / f;
    assert_eq!(x.len(), channels * hw * hw, "input must be c*hw^2");
    assert_eq!(out.len(), channels * o * o, "output must be c*(hw/f)^2");
    for c in 0..channels {
        let plane = &x[c * hw * hw..(c + 1) * hw * hw];
        for oy in 0..o {
            for ox in 0..o {
                let mut m = f32::NEG_INFINITY;
                for dy in 0..f {
                    for dx in 0..f {
                        m = m.max(plane[(oy * f + dy) * hw + ox * f + dx]);
                    }
                }
                out[(c * o + oy) * o + ox] = m;
            }
        }
    }
}

// ----------------------------------------------------------------------
// Integer tier: i8 weight codes × i16 activation codes, i32 accumulate
// ----------------------------------------------------------------------
//
// Quantization in `runtime::simnet` snaps every operand to
// `code · scale` with an integer code and a **power-of-two** scale, so a
// quantized layer's f32 math is secretly integer math: each product is
// `ax·aw · (sa·sw)` and each partial sum is `N · (sa·sw)` for an integer
// N. When the layer satisfies `k · (2^w−1)(2^a−1) < 2^24`
// (`quant::Policy::int_exact`), every such N fits a 24-bit mantissa, so
// the f32 kernels above never round — their result is *exactly*
// `(Σ ax·aw) · sa·sw`, independent of blocking, tiling, zero-skipping or
// summation order. These kernels compute the same Σ in i32 (exact by the
// same bound), dequantize once per output element with a single
// power-of-two multiply, and are therefore **bitwise identical** to the
// f32 path on every eligible layer — the dispatcher in
// `SimBackend` enforces the predicate and the bench's `int_bit_exact`
// hard gate enforces the identity.
//
// Operand widths: weight codes are symmetric ≤ 2^(w−1)−1 ≤ 127 (i8),
// activation codes ≤ 2^a−1 ≤ 255 (i16), so each product fits i16's
// 32767 and the whole reduction fits i32 with the predicate's 2^24
// headroom. Zero codes need no skip — integer adds of 0 are exact no-ops.

/// A weight-code matrix packed into column panels, mirroring
/// [`PackedMat`]'s layout exactly (same [`PANEL_COLS`] width, row-major
/// within the panel) but holding i8 quantization codes: the f32 value is
/// `code · scale` for the layer's power-of-two weight scale, carried
/// alongside by the owner.
#[derive(Clone, Debug)]
pub struct PackedMatI8 {
    /// Reduction dimension (input features / lowered rows).
    pub rows: usize,
    /// Output dimension (output features / lowered cols).
    pub cols: usize,
    data: Vec<i8>,
}

impl PackedMatI8 {
    /// Pack a row-major `rows × cols` code matrix into column panels.
    pub fn pack(w: &[i8], rows: usize, cols: usize) -> PackedMatI8 {
        assert_eq!(w.len(), rows * cols, "code buffer must be rows*cols");
        let mut data = vec![0i8; rows * cols];
        let mut off = 0;
        let mut j0 = 0;
        while j0 < cols {
            let pw = PANEL_COLS.min(cols - j0);
            for i in 0..rows {
                data[off..off + pw].copy_from_slice(&w[i * cols + j0..i * cols + j0 + pw]);
                off += pw;
            }
            j0 += pw;
        }
        PackedMatI8 { rows, cols, data }
    }

    /// Unpack back to the row-major layout (tests / debugging).
    pub fn unpack(&self) -> Vec<i8> {
        let (rows, cols) = (self.rows, self.cols);
        let mut w = vec![0i8; rows * cols];
        let mut off = 0;
        let mut j0 = 0;
        while j0 < cols {
            let pw = PANEL_COLS.min(cols - j0);
            for i in 0..rows {
                w[i * cols + j0..i * cols + j0 + pw].copy_from_slice(&self.data[off..off + pw]);
                off += pw;
            }
            j0 += pw;
        }
        w
    }
}

/// Integer-tier pooled matmul: `out[m×n] = (x · w) · scale` where `x`
/// holds i16 activation codes, `w` packed i8 weight codes and `scale` the
/// power-of-two product of the two quantization scales. Fan-out mirrors
/// [`matmul_pooled`] (same flops threshold, same row split), and on every
/// eligible layer the result is bit-for-bit equal to [`matmul_pooled`]
/// over the dequantized operands (see the tier comment above).
pub fn matmul_pooled_i8(
    x: &[i16],
    w: &PackedMatI8,
    m: usize,
    scale: f32,
    pool: &WorkerPool,
    out: &mut [f32],
) {
    let flops = 2usize
        .saturating_mul(m)
        .saturating_mul(w.rows)
        .saturating_mul(w.cols);
    let threads = if flops < POOL_MIN_FLOPS {
        1
    } else {
        pool.threads().min(m)
    };
    matmul_pooled_i8_threads(x, w, m, scale, pool, threads.max(1), out);
}

/// [`matmul_pooled_i8`] with an explicit worker count (1 = fully inline).
/// The split is by batch rows in [`TILE_ROWS`] multiples; each output
/// element's i32 reduction runs entirely inside one part, so results are
/// identical for every `threads` value.
pub fn matmul_pooled_i8_threads(
    x: &[i16],
    w: &PackedMatI8,
    m: usize,
    scale: f32,
    pool: &WorkerPool,
    threads: usize,
    out: &mut [f32],
) {
    let (k, n) = (w.rows, w.cols);
    assert_eq!(x.len(), m * k, "x must be m*k");
    assert_eq!(out.len(), m * n, "out must be m*n");
    out.fill(0.0);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let threads = threads.clamp(1, m);
    let data = w.data.as_slice();
    if threads == 1 {
        gemm_chunk_tiled_i8(x, m, k, n, data, scale, out);
        return;
    }
    // Same ~2-parts-per-thread split as the f32 pooled kernel.
    let target = threads * 2;
    let mut rows_per = (m + target - 1) / target;
    rows_per = ((rows_per + TILE_ROWS - 1) / TILE_ROWS) * TILE_ROWS;
    let parts = (m + rows_per - 1) / rows_per;
    let optr = SendPtr(out.as_mut_ptr());
    pool.run(parts, |p| {
        let r0 = p * rows_per;
        let rows = rows_per.min(m - r0);
        let xs = &x[r0 * k..(r0 + rows) * k];
        // SAFETY: part `p` owns rows [r0, r0 + rows) of `out` exclusively
        // (parts tile the row range without overlap), and `out` outlives
        // `pool.run`, which blocks until every part has finished.
        let os = unsafe { std::slice::from_raw_parts_mut(optr.0.add(r0 * n), rows * n) };
        gemm_chunk_tiled_i8(xs, rows, k, n, data, scale, os);
    });
}

/// Integer register-tiled microkernel over one chunk of batch rows.
/// Unlike [`gemm_chunk_tiled`] there is no reduction-block resume: i32
/// accumulation is exact, so each tile runs the **full** k reduction in
/// registers and writes its dequantized f32 result exactly once — the
/// destination needs no zeroing and order is irrelevant by exactness.
fn gemm_chunk_tiled_i8(
    x: &[i16],
    rows: usize,
    k: usize,
    n: usize,
    data: &[i8],
    scale: f32,
    out: &mut [f32],
) {
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(out.len(), rows * n);
    let mut j0 = 0;
    let mut poff = 0;
    while j0 < n {
        let pw = PANEL_COLS.min(n - j0);
        let panel = &data[poff..poff + k * pw];
        let mut jc = 0;
        while jc < pw {
            let nc = TILE_COLS.min(pw - jc);
            let mut r0 = 0;
            if nc == TILE_COLS {
                while r0 + TILE_ROWS <= rows {
                    tile_mxn_i8::<TILE_COLS>(x, k, r0, panel, pw, jc, scale, out, n, j0);
                    r0 += TILE_ROWS;
                }
            } else if nc == 8 {
                while r0 + TILE_ROWS <= rows {
                    tile_mxn_i8::<8>(x, k, r0, panel, pw, jc, scale, out, n, j0);
                    r0 += TILE_ROWS;
                }
            }
            while r0 < rows {
                tile_edge_row_i8(x, k, r0, panel, pw, jc, nc, scale, out, n, j0);
                r0 += 1;
            }
            jc += nc;
        }
        j0 += pw;
        poff += k * pw;
    }
}

/// One full TILE_ROWS×NC integer register tile: i32 accumulators over the
/// whole reduction, then one dequantizing store per element. `NC` is a
/// compile-time constant (16 or 8) so the widening multiply-add bodies
/// fully unroll and autovectorize.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn tile_mxn_i8<const NC: usize>(
    x: &[i16],
    k: usize,
    r0: usize,
    panel: &[i8],
    pw: usize,
    jc: usize,
    scale: f32,
    out: &mut [f32],
    n: usize,
    j0: usize,
) {
    let mut acc = [[0i32; NC]; TILE_ROWS];
    for di in 0..k {
        let wbase = di * pw + jc;
        let wrow = &panel[wbase..wbase + NC];
        for (r, a) in acc.iter_mut().enumerate() {
            let xi = x[(r0 + r) * k + di] as i32;
            for (av, &wv) in a.iter_mut().zip(wrow) {
                *av += xi * wv as i32;
            }
        }
    }
    for (r, a) in acc.iter().enumerate() {
        let base = (r0 + r) * n + j0 + jc;
        for (o, &av) in out[base..base + NC].iter_mut().zip(a) {
            *o = av as f32 * scale;
        }
    }
}

/// Scalar edge path for leftover rows and odd column-slice widths (same
/// exact i32 reduction, per-element dequantizing store).
#[allow(clippy::too_many_arguments)]
fn tile_edge_row_i8(
    x: &[i16],
    k: usize,
    row: usize,
    panel: &[i8],
    pw: usize,
    jc: usize,
    nc: usize,
    scale: f32,
    out: &mut [f32],
    n: usize,
    j0: usize,
) {
    let mut acc = [0i32; TILE_COLS];
    let acc = &mut acc[..nc];
    for di in 0..k {
        let xi = x[row * k + di] as i32;
        if xi == 0 {
            continue;
        }
        let wbase = di * pw + jc;
        let wrow = &panel[wbase..wbase + nc];
        for (a, &wv) in acc.iter_mut().zip(wrow) {
            *a += xi * wv as i32;
        }
    }
    let base = row * n + j0 + jc;
    for (o, &av) in out[base..base + nc].iter_mut().zip(acc.iter()) {
        *o = av as f32 * scale;
    }
}

/// [`im2col_chunk`] over i16 activation codes: identical tap order and
/// geometry, zero padding reads code 0 (which dequantizes to +0.0, the
/// exact value the f32 lowering inserts).
pub fn im2col_chunk_i16(x: &[i16], g: &ConvGeom, pos0: usize, npos: usize, patches: &mut [i16]) {
    let pl = g.patch_len();
    assert_eq!(x.len(), g.in_features(), "sample must be in_c*in_hw^2");
    assert_eq!(patches.len(), npos * pl, "patch buffer must be npos*patch_len");
    assert!(pos0 + npos <= g.num_positions(), "positions out of range");
    for p in 0..npos {
        let pos = pos0 + p;
        let (oy, ox) = (pos / g.out_hw, pos % g.out_hw);
        let dst = &mut patches[p * pl..(p + 1) * pl];
        let mut d = 0;
        for c in 0..g.in_c {
            let plane = &x[c * g.in_hw * g.in_hw..(c + 1) * g.in_hw * g.in_hw];
            for ky in 0..g.kernel {
                let iy = (oy * g.stride + ky) as isize - g.padding as isize;
                let in_row = iy >= 0 && (iy as usize) < g.in_hw;
                for kx in 0..g.kernel {
                    let ix = (ox * g.stride + kx) as isize - g.padding as isize;
                    dst[d] = if in_row && ix >= 0 && (ix as usize) < g.in_hw {
                        plane[iy as usize * g.in_hw + ix as usize]
                    } else {
                        0
                    };
                    d += 1;
                }
            }
        }
    }
}

/// Integer-tier patch-streaming conv rows, mirroring
/// [`conv_rows_streamed`]: `prod[m × w.cols] = (P · w) · scale` over the
/// im2col code-patch matrix of positions `[pos0, pos0 + m)`, never
/// materialized. `strips` is the i16 twin of the f32 strip scratch (same
/// `parts × TILE_ROWS × patch_len` sizing contract); `prod` stays f32 —
/// each element is dequantized exactly once, so everything downstream
/// (scatter, ReLU, pooling) is untouched. Bit-for-bit equal to the f32
/// streamed path over the dequantized operands on eligible layers.
#[allow(clippy::too_many_arguments)]
pub fn conv_rows_streamed_i8(
    xs: &[i16],
    g: &ConvGeom,
    pos0: usize,
    m: usize,
    w: &PackedMatI8,
    scale: f32,
    pool: &WorkerPool,
    threads: usize,
    strips: &mut [i16],
    prod: &mut [f32],
) {
    let n = w.cols;
    let pl = g.patch_len();
    assert_eq!(w.rows, pl, "packed conv codes must have patch_len rows");
    assert_eq!(prod.len(), m * n, "prod must be m*cols");
    assert!(pos0 + m <= g.num_positions(), "positions out of range");
    if m == 0 || n == 0 {
        return;
    }
    let threads = threads.clamp(1, m);
    let tiles = (m + TILE_ROWS - 1) / TILE_ROWS;
    let parts = threads.min(tiles);
    let spl = TILE_ROWS * pl;
    assert!(strips.len() >= parts * spl, "strip scratch too small");
    if parts == 1 {
        conv_rows_task_i8(xs, g, pos0, m, w, scale, &mut strips[..spl], prod);
        return;
    }
    let tiles_per = (tiles + parts - 1) / parts;
    let rows_per = tiles_per * TILE_ROWS;
    let nparts = (m + rows_per - 1) / rows_per;
    let sptr = pool::SendMut(strips.as_mut_ptr());
    let pptr = SendPtr(prod.as_mut_ptr());
    pool.run(nparts, |p| {
        let r0 = p * rows_per;
        let rows = rows_per.min(m - r0);
        // SAFETY: part `p` exclusively owns strip panel `p` and prod rows
        // [r0, r0 + rows) — parts tile both without overlap — and both
        // buffers outlive `pool.run`, which blocks until every part has
        // finished.
        let strip = unsafe { std::slice::from_raw_parts_mut(sptr.0.add(p * spl), spl) };
        let pr = unsafe { std::slice::from_raw_parts_mut(pptr.0.add(r0 * n), rows * n) };
        conv_rows_task_i8(xs, g, pos0 + r0, rows, w, scale, strip, pr);
    });
}

/// [`conv_rows_streamed_i8`] with the worker count chosen from the
/// chunk's flops (same threshold as [`conv_rows_streamed_auto`], so the
/// two tiers fan out identically).
#[allow(clippy::too_many_arguments)]
pub fn conv_rows_streamed_auto_i8(
    xs: &[i16],
    g: &ConvGeom,
    pos0: usize,
    m: usize,
    w: &PackedMatI8,
    scale: f32,
    pool: &WorkerPool,
    strips: &mut [i16],
    prod: &mut [f32],
) {
    let flops = 2usize
        .saturating_mul(m)
        .saturating_mul(w.rows)
        .saturating_mul(w.cols);
    let threads = if flops < POOL_MIN_FLOPS {
        1
    } else {
        pool.threads()
    };
    conv_rows_streamed_i8(xs, g, pos0, m, w, scale, pool, threads.max(1), strips, prod);
}

/// One part's integer strip loop: pack `TILE_ROWS` code-patch rows into
/// the i16 panel, run the integer microkernel, advance. No prod zeroing —
/// the integer microkernel writes every covered element exactly once.
#[allow(clippy::too_many_arguments)]
fn conv_rows_task_i8(
    xs: &[i16],
    g: &ConvGeom,
    pos0: usize,
    m: usize,
    w: &PackedMatI8,
    scale: f32,
    strip: &mut [i16],
    prod: &mut [f32],
) {
    let (k, n) = (w.rows, w.cols);
    let pl = g.patch_len();
    let mut r0 = 0;
    while r0 < m {
        let h = TILE_ROWS.min(m - r0);
        im2col_chunk_i16(xs, g, pos0 + r0, h, &mut strip[..h * pl]);
        gemm_chunk_tiled_i8(
            &strip[..h * pl],
            h,
            k,
            n,
            &w.data,
            scale,
            &mut prod[r0 * n..(r0 + h) * n],
        );
        r0 += h;
    }
}

// --- f64 packed-panel kernels (RL policy-net minibatch GEMM) -------------
//
// `rl::mlp` trains in f64, so the replay-minibatch forward/backward passes
// get their own packed-panel path rather than reusing the f32 kernels.
// The bitwise contract differs from the f32 path in one deliberate way:
// these kernels do NOT skip zero inputs, because the per-sample training
// loops they replace add every `±0.0` product — each output element is the
// plain ascending-k f64 sum starting from `0.0` (or from the existing
// element for the accumulating variant), so routing a minibatch through
// them reproduces the hand-rolled loops bit for bit.

/// Column-panel width of the f64 packed layout (half the f32 width, same
/// panel footprint in bytes).
pub const PANEL_COLS_F64: usize = 32;

/// An f64 matrix packed into column panels, mirroring [`PackedMat`]: panel
/// `p` holds columns `[p·PANEL_COLS_F64, min((p+1)·PANEL_COLS_F64, cols))`,
/// row-major within the panel.
#[derive(Clone, Debug)]
pub struct PackedMatF64 {
    /// Reduction dimension.
    pub rows: usize,
    /// Output dimension.
    pub cols: usize,
    data: Vec<f64>,
}

impl PackedMatF64 {
    /// Pack a row-major `rows × cols` matrix into column panels.
    pub fn pack(w: &[f64], rows: usize, cols: usize) -> PackedMatF64 {
        assert_eq!(w.len(), rows * cols, "weight buffer must be rows*cols");
        let mut data = vec![0f64; rows * cols];
        let mut off = 0;
        let mut j0 = 0;
        while j0 < cols {
            let pw = PANEL_COLS_F64.min(cols - j0);
            for i in 0..rows {
                data[off..off + pw].copy_from_slice(&w[i * cols + j0..i * cols + j0 + pw]);
                off += pw;
            }
            j0 += pw;
        }
        PackedMatF64 { rows, cols, data }
    }

    /// Pack the *transpose* of a row-major `cols × rows` matrix: the packed
    /// result is `rows × cols` with element `(k, j) = w[j * rows + k]`. Lets
    /// a `[out][in]` weight matrix serve as the `in × out` operand of a
    /// forward pass without materializing the transpose.
    pub fn pack_transposed(w: &[f64], rows: usize, cols: usize) -> PackedMatF64 {
        assert_eq!(w.len(), rows * cols, "weight buffer must be rows*cols");
        let mut data = vec![0f64; rows * cols];
        let mut off = 0;
        let mut j0 = 0;
        while j0 < cols {
            let pw = PANEL_COLS_F64.min(cols - j0);
            for k in 0..rows {
                for c in 0..pw {
                    data[off] = w[(j0 + c) * rows + k];
                    off += 1;
                }
            }
            j0 += pw;
        }
        PackedMatF64 { rows, cols, data }
    }
}

/// `out[m×n] = x[m×rows] · w` over the packed f64 layout. Every output
/// element is the ascending-k reduction from `0.0` — no zero-skipping, no
/// re-association — so it is bit-identical to the textbook per-element sum.
pub fn matmul_f64(x: &[f64], w: &PackedMatF64, m: usize, out: &mut [f64]) {
    matmul_f64_impl(x, w, m, out, false);
}

/// `out[m×n] += x[m×rows] · w`: like [`matmul_f64`] but each element's
/// reduction resumes from the existing value (gradient accumulation).
pub fn matmul_f64_acc(x: &[f64], w: &PackedMatF64, m: usize, out: &mut [f64]) {
    matmul_f64_impl(x, w, m, out, true);
}

fn matmul_f64_impl(x: &[f64], w: &PackedMatF64, m: usize, out: &mut [f64], accumulate: bool) {
    let (k, n) = (w.rows, w.cols);
    assert_eq!(x.len(), m * k, "x must be m*rows");
    assert_eq!(out.len(), m * n, "out must be m*cols");
    let mut acc = [0f64; PANEL_COLS_F64];
    let mut j0 = 0;
    while j0 < n {
        let pw = PANEL_COLS_F64.min(n - j0);
        let panel = &w.data[j0 * k..j0 * k + pw * k];
        for row in 0..m {
            let xin = &x[row * k..(row + 1) * k];
            let yout = &mut out[row * n + j0..row * n + j0 + pw];
            if accumulate {
                acc[..pw].copy_from_slice(yout);
            } else {
                acc[..pw].fill(0.0);
            }
            for (kk, &xv) in xin.iter().enumerate() {
                let wrow = &panel[kk * pw..(kk + 1) * pw];
                for (a, &wv) in acc[..pw].iter_mut().zip(wrow) {
                    *a += xv * wv;
                }
            }
            yout.copy_from_slice(&acc[..pw]);
        }
        j0 += pw;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn random_mat(rng: &mut Rng, len: usize, zero_every: usize) -> Vec<f32> {
        (0..len)
            .map(|i| {
                if zero_every > 0 && i % zero_every == 0 {
                    0.0
                } else {
                    (rng.normal() * 0.5) as f32
                }
            })
            .collect()
    }

    #[test]
    fn pack_roundtrips() {
        let mut rng = Rng::new(11);
        for &(rows, cols) in &[(1usize, 1usize), (3, 5), (64, 64), (17, 130), (5, 200)] {
            let w = random_mat(&mut rng, rows * cols, 0);
            let packed = PackedMat::pack(&w, rows, cols);
            assert_eq!(packed.unpack(), w, "{rows}x{cols}");
        }
    }

    #[test]
    fn blocked_matches_naive_bit_for_bit_across_odd_shapes() {
        // Shapes chosen to straddle the panel/block boundaries: below,
        // exactly at, and not-a-multiple-of PANEL_COLS/BLOCK_ROWS.
        let shapes = [
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (2, 64, 64),
            (4, 65, 63),
            (1, 100, 130),
            (5, 129, 65),
            (17, 23, 31),
            (16, 200, 70),
        ];
        let mut rng = Rng::new(7);
        for &(m, k, n) in &shapes {
            let x = random_mat(&mut rng, m * k, 3); // every 3rd input exactly 0
            let w = random_mat(&mut rng, k * n, 0);
            let packed = PackedMat::pack(&w, k, n);
            let mut naive = vec![0f32; m * n];
            let mut blocked = vec![0f32; m * n];
            matmul_naive(&x, &w, m, k, n, &mut naive);
            matmul_blocked_threads(&x, &packed, m, 1, &mut blocked);
            let nb = naive.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            let bb = blocked.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            assert_eq!(nb, bb, "bitwise divergence at shape {m}x{k}x{n}");
        }
    }

    fn random_mat_f64(rng: &mut Rng, len: usize, zero_every: usize) -> Vec<f64> {
        (0..len)
            .map(|i| {
                if zero_every > 0 && i % zero_every == 0 {
                    0.0
                } else {
                    rng.normal() * 0.5
                }
            })
            .collect()
    }

    /// Textbook ascending-k per-element sum — the order the per-sample
    /// `rl::mlp` loops use (zeros included).
    fn matmul_f64_ref(x: &[f64], w: &[f64], m: usize, k: usize, n: usize, out: &mut [f64]) {
        for row in 0..m {
            for j in 0..n {
                let mut acc = out[row * n + j];
                for kk in 0..k {
                    acc += x[row * k + kk] * w[kk * n + j];
                }
                out[row * n + j] = acc;
            }
        }
    }

    #[test]
    fn f64_pack_transposed_matches_explicit_transpose() {
        let mut rng = Rng::new(29);
        for &(rows, cols) in &[(1usize, 1usize), (3, 5), (32, 32), (17, 70), (48, 33)] {
            // wt is the row-major cols×rows original; pack_transposed packs
            // its transpose (rows×cols).
            let wt = random_mat_f64(&mut rng, rows * cols, 0);
            let mut w = vec![0f64; rows * cols];
            for j in 0..cols {
                for k in 0..rows {
                    w[k * cols + j] = wt[j * rows + k];
                }
            }
            let a = PackedMatF64::pack(&w, rows, cols);
            let b = PackedMatF64::pack_transposed(&wt, rows, cols);
            assert_eq!(a.data, b.data, "{rows}x{cols}");
        }
    }

    #[test]
    fn f64_kernel_matches_reference_bit_for_bit() {
        // Shapes straddle PANEL_COLS_F64; inputs include exact zeros, which
        // the f64 path must NOT skip (its contract is the plain sum).
        let shapes = [
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (2, 32, 32),
            (7, 33, 31),
            (48, 17, 48),
            (1, 100, 70),
        ];
        let mut rng = Rng::new(31);
        for &(m, k, n) in &shapes {
            let x = random_mat_f64(&mut rng, m * k, 3);
            let w = random_mat_f64(&mut rng, k * n, 0);
            let packed = PackedMatF64::pack(&w, k, n);
            let mut reference = vec![0f64; m * n];
            matmul_f64_ref(&x, &w, m, k, n, &mut reference);
            let mut out = vec![0f64; m * n];
            matmul_f64(&x, &packed, m, &mut out);
            let rb = reference.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            let ob = out.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            assert_eq!(rb, ob, "bitwise divergence at shape {m}x{k}x{n}");
            // Accumulating variant resumes each element's reduction.
            let mut acc_ref = random_mat_f64(&mut rng, m * n, 0);
            let mut acc_out = acc_ref.clone();
            matmul_f64_ref(&x, &w, m, k, n, &mut acc_ref);
            matmul_f64_acc(&x, &packed, m, &mut acc_out);
            let rb = acc_ref.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            let ob = acc_out.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            assert_eq!(rb, ob, "acc divergence at shape {m}x{k}x{n}");
        }
    }

    #[test]
    fn thread_split_does_not_change_results() {
        let mut rng = Rng::new(13);
        let (m, k, n) = (9, 150, 140);
        let x = random_mat(&mut rng, m * k, 4);
        let w = random_mat(&mut rng, k * n, 0);
        let packed = PackedMat::pack(&w, k, n);
        let mut seq = vec![0f32; m * n];
        matmul_blocked_threads(&x, &packed, m, 1, &mut seq);
        for threads in [2, 3, 8, 64] {
            let mut mt = vec![0f32; m * n];
            matmul_blocked_threads(&x, &packed, m, threads, &mut mt);
            assert_eq!(seq, mt, "threads={threads}");
        }
        // The auto-threaded entry point agrees too.
        let mut auto = vec![0f32; m * n];
        matmul_blocked(&x, &packed, m, &mut auto);
        assert_eq!(seq, auto);
    }

    #[test]
    fn pooled_matches_naive_bit_for_bit_across_odd_shapes_and_threads() {
        // Shapes straddle every tile boundary: below/at/not-a-multiple-of
        // TILE_ROWS, TILE_COLS, the 8-wide tile, PANEL_COLS and
        // BLOCK_ROWS; thread counts include odd and above-m values.
        let shapes = [
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (4, 65, 63),
            (5, 129, 65),
            (17, 23, 31),
            (16, 200, 70),
            (3, 70, 8),
            (9, 64, 24),
            (7, 40, 5),
            (21, 90, 130),
        ];
        let mut rng = Rng::new(23);
        let pool = crate::runtime::pool::WorkerPool::new(4);
        for &(m, k, n) in &shapes {
            let x = random_mat(&mut rng, m * k, 3); // every 3rd input exactly 0
            let w = random_mat(&mut rng, k * n, 0);
            let packed = PackedMat::pack(&w, k, n);
            let mut naive = vec![0f32; m * n];
            matmul_naive(&x, &w, m, k, n, &mut naive);
            let nb = naive.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            for threads in [1usize, 2, 4, 7] {
                let mut pooled = vec![0f32; m * n];
                matmul_pooled_threads(&x, &packed, m, &pool, threads, &mut pooled);
                let pb = pooled.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
                assert_eq!(nb, pb, "divergence at {m}x{k}x{n} threads={threads}");
            }
            // The auto-threaded entry point agrees too.
            let mut auto = vec![0f32; m * n];
            matmul_pooled(&x, &packed, m, &pool, &mut auto);
            assert_eq!(naive, auto, "auto divergence at {m}x{k}x{n}");
        }
    }

    #[test]
    fn pooled_conv_lowering_matches_direct_conv_bit_for_bit() {
        // im2col + the pooled tiled kernel must equal the direct-conv
        // reference, chunked to exercise the pos0 offsets.
        let g = ConvGeom {
            in_c: 3,
            out_c: 4,
            kernel: 3,
            stride: 2,
            padding: 1,
            in_hw: 6,
            out_hw: 3,
        };
        let mut rng = Rng::new(99);
        let x = random_mat(&mut rng, g.in_features(), 5);
        let w = random_mat(&mut rng, g.patch_len() * g.out_c, 0);

        let mut direct = vec![0f32; g.out_c * g.num_positions()];
        conv2d_ref(&x, &w, &g, &mut direct);

        let pool = crate::runtime::pool::WorkerPool::new(3);
        let npos = g.num_positions();
        let mut lowered = vec![0f32; g.out_c * npos];
        let chunk = 4;
        let mut patches = vec![0f32; chunk * g.patch_len()];
        let mut prod = vec![0f32; chunk * g.out_c];
        let packed = PackedMat::pack(&w, g.patch_len(), g.out_c);
        let mut pos0 = 0;
        while pos0 < npos {
            let m = chunk.min(npos - pos0);
            im2col_chunk(&x, &g, pos0, m, &mut patches[..m * g.patch_len()]);
            matmul_pooled_threads(
                &patches[..m * g.patch_len()],
                &packed,
                m,
                &pool,
                2,
                &mut prod[..m * g.out_c],
            );
            for p in 0..m {
                for oc in 0..g.out_c {
                    lowered[oc * npos + pos0 + p] = prod[p * g.out_c + oc];
                }
            }
            pos0 += m;
        }
        let db = direct.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        let lb = lowered.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(db, lb, "pooled im2col path must equal direct convolution");
    }

    #[test]
    fn im2col_matmul_matches_direct_conv_bit_for_bit() {
        // Fixed-seed 3-channel 6x6 input, 4 output channels, stride 2,
        // asymmetric coverage of the zero padding.
        let g = ConvGeom {
            in_c: 3,
            out_c: 4,
            kernel: 3,
            stride: 2,
            padding: 1,
            in_hw: 6,
            out_hw: 3,
        };
        let mut rng = Rng::new(42);
        let x = random_mat(&mut rng, g.in_features(), 5);
        let w = random_mat(&mut rng, g.patch_len() * g.out_c, 0);

        let mut direct = vec![0f32; g.out_c * g.num_positions()];
        conv2d_ref(&x, &w, &g, &mut direct);

        // Lowered path, chunked to exercise the pos0 offsets.
        let npos = g.num_positions();
        let mut lowered = vec![0f32; g.out_c * npos];
        let chunk = 4;
        let mut patches = vec![0f32; chunk * g.patch_len()];
        let mut prod = vec![0f32; chunk * g.out_c];
        let packed = PackedMat::pack(&w, g.patch_len(), g.out_c);
        let mut pos0 = 0;
        while pos0 < npos {
            let mchunk = chunk.min(npos - pos0);
            im2col_chunk(&x, &g, pos0, mchunk, &mut patches[..mchunk * g.patch_len()]);
            matmul_blocked(
                &patches[..mchunk * g.patch_len()],
                &packed,
                mchunk,
                &mut prod[..mchunk * g.out_c],
            );
            for p in 0..mchunk {
                for oc in 0..g.out_c {
                    lowered[oc * npos + pos0 + p] = prod[p * g.out_c + oc];
                }
            }
            pos0 += mchunk;
        }
        let db = direct.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        let lb = lowered.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(db, lb, "im2col+matmul must equal direct convolution");
    }

    #[test]
    fn streamed_conv_rows_match_materialized_im2col_bit_for_bit() {
        // 7x7 output grid: 49 positions — not a TILE_ROWS multiple, so
        // the strip loop's edge path and the part split both get hit.
        let g = ConvGeom {
            in_c: 3,
            out_c: 5,
            kernel: 3,
            stride: 1,
            padding: 1,
            in_hw: 7,
            out_hw: 7,
        };
        let mut rng = Rng::new(77);
        let x = random_mat(&mut rng, g.in_features(), 4);
        let w = random_mat(&mut rng, g.patch_len() * g.out_c, 0);
        let packed = PackedMat::pack(&w, g.patch_len(), g.out_c);
        let npos = g.num_positions();
        let pl = g.patch_len();

        // Materialized reference: full im2col + the naive kernel.
        let mut patches = vec![0f32; npos * pl];
        im2col_chunk(&x, &g, 0, npos, &mut patches);
        let mut want = vec![0f32; npos * g.out_c];
        matmul_naive(&patches, &w, npos, pl, g.out_c, &mut want);
        let wb = want.iter().map(|v| v.to_bits()).collect::<Vec<_>>();

        let pool = crate::runtime::pool::WorkerPool::new(4);
        for threads in [1usize, 2, 3, 7] {
            let mut strips = vec![0f32; threads * TILE_ROWS * pl];
            let mut prod = vec![0f32; npos * g.out_c];
            conv_rows_streamed(
                &x,
                &g,
                0,
                npos,
                &packed,
                &pool,
                threads,
                &mut strips,
                &mut prod,
            );
            let pb = prod.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            assert_eq!(wb, pb, "streamed divergence at threads={threads}");
        }

        // Offset windows (pos0 > 0, odd m) agree with the same slice of
        // the full product.
        let (pos0, m) = (13usize, 10usize);
        let mut strips = vec![0f32; 2 * TILE_ROWS * pl];
        let mut prod = vec![0f32; m * g.out_c];
        conv_rows_streamed(&x, &g, pos0, m, &packed, &pool, 2, &mut strips, &mut prod);
        assert_eq!(
            prod.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want[pos0 * g.out_c..(pos0 + m) * g.out_c]
                .iter()
                .map(|v| v.to_bits())
                .collect::<Vec<_>>(),
            "offset streamed window diverged"
        );

        // The auto-threaded entry point agrees too.
        let mut strips = vec![0f32; pool.threads() * TILE_ROWS * pl];
        let mut prod = vec![0f32; npos * g.out_c];
        conv_rows_streamed_auto(&x, &g, 0, npos, &packed, &pool, &mut strips, &mut prod);
        assert_eq!(want, prod, "auto streamed divergence");
    }

    /// Random i16 activation codes in `[0, 2^a − 1]` (the unsigned
    /// post-ReLU grid) with exact zeros mixed in; negate when `signed`.
    fn random_act_codes(
        rng: &mut Rng,
        len: usize,
        a_bits: u32,
        zero_every: usize,
        signed: bool,
    ) -> Vec<i16> {
        let hi = (1i64 << a_bits) - 1;
        let lo = if signed { -((1i64 << (a_bits - 1)) - 1) } else { 0 };
        let hi = if signed { (1i64 << (a_bits - 1)) - 1 } else { hi };
        (0..len)
            .map(|i| {
                if zero_every > 0 && i % zero_every == 0 {
                    0
                } else {
                    rng.int_range(lo, hi) as i16
                }
            })
            .collect()
    }

    /// Random i8 weight codes in the symmetric `±(2^(w−1) − 1)` grid.
    fn random_weight_codes(rng: &mut Rng, len: usize, w_bits: u32) -> Vec<i8> {
        let lim = (1i64 << (w_bits - 1)) - 1;
        (0..len).map(|_| rng.int_range(-lim, lim) as i8).collect()
    }

    #[test]
    fn packed_i8_roundtrips() {
        let mut rng = Rng::new(15);
        for &(rows, cols) in &[(1usize, 1usize), (3, 5), (64, 64), (17, 130), (5, 200)] {
            let w = random_weight_codes(&mut rng, rows * cols, 8);
            let packed = PackedMatI8::pack(&w, rows, cols);
            assert_eq!(packed.unpack(), w, "{rows}x{cols}");
        }
    }

    #[test]
    fn int_tier_matches_f32_kernels_bit_for_bit_across_shapes_and_threads() {
        // Every shape here is eligible at full 8/8 precision
        // (k ≤ 200 < 258, so k·255·255 < 2^24): the integer path must
        // equal BOTH the naive and the pooled f32 kernels over the
        // dequantized operands, bit for bit, at every thread count.
        let shapes = [
            (1usize, 1usize, 1usize),
            (3, 5, 7),
            (4, 65, 63),
            (5, 129, 65),
            (17, 23, 31),
            (16, 200, 70),
            (3, 70, 8),
            (9, 64, 24),
            (7, 40, 5),
            (21, 90, 130),
        ];
        // Power-of-two scales, as the simnet quantizers now guarantee.
        let (sa, sw) = (1.0f32 / 128.0, 1.0f32 / 512.0);
        let mut rng = Rng::new(37);
        let pool = crate::runtime::pool::WorkerPool::new(4);
        for (si, &(m, k, n)) in shapes.iter().enumerate() {
            // Odd shapes use the signed activation grid (the first-layer
            // case); the rest use the unsigned post-ReLU grid.
            let ax = random_act_codes(&mut rng, m * k, 8, 3, si % 2 == 1);
            let aw = random_weight_codes(&mut rng, k * n, 8);
            let xf: Vec<f32> = ax.iter().map(|&c| c as f32 * sa).collect();
            let wf: Vec<f32> = aw.iter().map(|&c| c as f32 * sw).collect();
            let packed_f = PackedMat::pack(&wf, k, n);
            let packed_i = PackedMatI8::pack(&aw, k, n);
            let mut naive = vec![0f32; m * n];
            matmul_naive(&xf, &wf, m, k, n, &mut naive);
            let nb = naive.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            for threads in [1usize, 2, 4, 7] {
                let mut f32_out = vec![0f32; m * n];
                matmul_pooled_threads(&xf, &packed_f, m, &pool, threads, &mut f32_out);
                let mut int_out = vec![f32::NAN; m * n];
                matmul_pooled_i8_threads(&ax, &packed_i, m, sa * sw, &pool, threads, &mut int_out);
                let fb = f32_out.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
                let ib = int_out.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
                assert_eq!(nb, fb, "f32 divergence at {m}x{k}x{n} threads={threads}");
                assert_eq!(fb, ib, "int divergence at {m}x{k}x{n} threads={threads}");
            }
            // The auto-threaded entry point agrees too.
            let mut auto = vec![0f32; m * n];
            matmul_pooled_i8(&ax, &packed_i, m, sa * sw, &pool, &mut auto);
            assert_eq!(naive, auto, "auto int divergence at {m}x{k}x{n}");
        }
    }

    #[test]
    fn streamed_conv_i8_matches_f32_streamed_bit_for_bit() {
        // Same 7x7 grid as the f32 streamed test (49 positions — not a
        // TILE_ROWS multiple); patch_len 27 is eligible at 8/8 with huge
        // margin. The integer streamed path must match the f32 streamed
        // path over the dequantized operands at every thread count,
        // including offset windows and the auto entry point.
        let g = ConvGeom {
            in_c: 3,
            out_c: 5,
            kernel: 3,
            stride: 1,
            padding: 1,
            in_hw: 7,
            out_hw: 7,
        };
        let (sa, sw) = (1.0f32 / 256.0, 1.0f32 / 64.0);
        let mut rng = Rng::new(53);
        let ax = random_act_codes(&mut rng, g.in_features(), 8, 4, false);
        let aw = random_weight_codes(&mut rng, g.patch_len() * g.out_c, 8);
        let xf: Vec<f32> = ax.iter().map(|&c| c as f32 * sa).collect();
        let wf: Vec<f32> = aw.iter().map(|&c| c as f32 * sw).collect();
        let packed_f = PackedMat::pack(&wf, g.patch_len(), g.out_c);
        let packed_i = PackedMatI8::pack(&aw, g.patch_len(), g.out_c);
        let npos = g.num_positions();
        let pl = g.patch_len();
        let pool = crate::runtime::pool::WorkerPool::new(4);

        let mut want = vec![0f32; npos * g.out_c];
        {
            let mut strips = vec![0f32; TILE_ROWS * pl];
            conv_rows_streamed(&xf, &g, 0, npos, &packed_f, &pool, 1, &mut strips, &mut want);
        }
        let wb = want.iter().map(|v| v.to_bits()).collect::<Vec<_>>();

        for threads in [1usize, 2, 3, 7] {
            let mut strips = vec![0i16; threads * TILE_ROWS * pl];
            let mut prod = vec![f32::NAN; npos * g.out_c];
            conv_rows_streamed_i8(
                &ax, &g, 0, npos, &packed_i, sa * sw, &pool, threads, &mut strips, &mut prod,
            );
            let pb = prod.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            assert_eq!(wb, pb, "int streamed divergence at threads={threads}");
        }

        // Offset window (pos0 > 0, odd m).
        let (pos0, m) = (13usize, 10usize);
        let mut strips = vec![0i16; 2 * TILE_ROWS * pl];
        let mut prod = vec![0f32; m * g.out_c];
        conv_rows_streamed_i8(
            &ax, &g, pos0, m, &packed_i, sa * sw, &pool, 2, &mut strips, &mut prod,
        );
        assert_eq!(
            prod.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want[pos0 * g.out_c..(pos0 + m) * g.out_c]
                .iter()
                .map(|v| v.to_bits())
                .collect::<Vec<_>>(),
            "offset int streamed window diverged"
        );

        // The auto-threaded entry point agrees too.
        let mut strips = vec![0i16; pool.threads() * TILE_ROWS * pl];
        let mut prod = vec![0f32; npos * g.out_c];
        conv_rows_streamed_auto_i8(
            &ax, &g, 0, npos, &packed_i, sa * sw, &pool, &mut strips, &mut prod,
        );
        assert_eq!(want, prod, "auto int streamed divergence");
    }

    #[test]
    fn im2col_stride_one_padding_keeps_geometry() {
        // 1 channel, 3x3 kernel, pad 1, stride 1: the center patch of a
        // one-hot input picks up exactly the kernel taps.
        let g = ConvGeom {
            in_c: 1,
            out_c: 1,
            kernel: 3,
            stride: 1,
            padding: 1,
            in_hw: 4,
            out_hw: 4,
        };
        let mut x = vec![0f32; 16];
        x[5] = 1.0; // (y=1, x=1)
        let mut patches = vec![0f32; g.num_positions() * g.patch_len()];
        im2col_chunk(&x, &g, 0, g.num_positions(), &mut patches);
        // Output position (1,1) sees the hot pixel at its center tap (1,1).
        let pos = 5; // oy=1, ox=1
        let patch = &patches[pos * 9..(pos + 1) * 9];
        assert_eq!(patch[4], 1.0);
        assert_eq!(patch.iter().filter(|&&v| v != 0.0).count(), 1);
        // Corner position (0,0): the hot pixel lands at tap (2,2).
        let corner = &patches[0..9];
        assert_eq!(corner[8], 1.0);
    }

    #[test]
    fn max_pool_reduces_grid() {
        // 1 channel 4x4 ramp; 2x2 max pooling keeps each window's max.
        let x: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let mut out = vec![0f32; 4];
        max_pool(&x, 1, 4, 2, &mut out);
        assert_eq!(out, vec![5.0, 7.0, 13.0, 15.0]);
    }
}
