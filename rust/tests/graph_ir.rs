//! Graph-IR integration: golden residual topologies (diamond, chained
//! blocks) execute bit-exactly against the straight-line reference
//! executor, malformed graphs yield typed errors, and a property test
//! checks that *any* supported network's graph execution matches the
//! reference bit for bit across worker-thread counts 1/2/4/7.

use lrmp::coordinator::InferenceBackend;
use lrmp::nets::{Layer, Network};
use lrmp::runtime::graph::{self, Graph, GraphError, Node, NodeId, Op};
use lrmp::runtime::simnet::SimBackend;
use lrmp::util::propcheck;
use lrmp::util::prng::Rng;

fn bits_of(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Evaluate `net` through the graph executor at several thread counts and
/// assert every result equals the straight-line reference bit for bit.
fn assert_matches_reference(net: &Network, b: usize, seed: u64) -> Result<(), String> {
    let nl = net.num_layers();
    let reference = SimBackend::from_network(net, b, seed)
        .map_err(|e| format!("{}: {e}", net.name))?;
    let dim = reference.input_dim();
    let x: Vec<f32> = (0..b * dim)
        .map(|i| ((i * 13 + 7) % 61) as f32 / 61.0 - 0.25)
        .collect();
    let wb = vec![5.0f32; nl];
    let ab = vec![6.0f32; nl];
    let want = bits_of(&reference.eval_reference(&x, &wb, &ab));
    for threads in [1usize, 2, 4, 7] {
        let mut backend = SimBackend::from_network_opts(net, b, seed, Some(threads))
            .map_err(|e| format!("{}: {e}", net.name))?;
        let y = backend
            .eval(x.clone(), wb.clone(), ab.clone())
            .map_err(|e| format!("{}: eval failed: {e}", net.name))?;
        if bits_of(&y) != want {
            return Err(format!(
                "{}: graph execution diverged from the reference at threads={threads}",
                net.name
            ));
        }
    }
    Ok(())
}

// ----------------------------------------------------------------------
// Golden topologies
// ----------------------------------------------------------------------

#[test]
fn diamond_residual_block_executes_bit_exactly() {
    // One stride-2 block whose skip is a 1x1 projection — the diamond:
    //   stem ─► conv1 ─► conv2 ─► add ─► fc
    //        └────── downsample ───┘
    let net = Network {
        name: "golden-diamond".into(),
        layers: vec![
            Layer::conv("stem", 3, 4, 3, 1, 1, 6),
            Layer::conv("block.0.conv1", 4, 8, 3, 2, 1, 6),
            Layer::conv("block.0.conv2", 8, 8, 3, 1, 1, 3),
            Layer::conv("block.0.downsample", 4, 8, 1, 2, 0, 6),
            Layer::linear("fc", 8, 5),
        ],
    };
    let g = graph::lower(&net).expect("diamond lowers");
    assert_eq!(g.residual_adds(), 1);
    assert_eq!(g.weight_nodes(), 5);
    // The skip tensor must keep its own arena slot across the trunk.
    assert!(g.num_slots() >= 3, "slots {}", g.num_slots());
    assert_matches_reference(&net, 3, 21).unwrap();
}

#[test]
fn chained_residual_blocks_execute_bit_exactly() {
    // Three identity-skip blocks back to back: consecutive Adds, each
    // feeding the next block's trunk and skip.
    let mut layers = vec![Layer::conv("stem", 3, 6, 3, 1, 1, 5)];
    for blk in 0..3 {
        layers.push(Layer::conv(&format!("layer1.{blk}.conv1"), 6, 6, 3, 1, 1, 5));
        layers.push(Layer::conv(&format!("layer1.{blk}.conv2"), 6, 6, 3, 1, 1, 5));
    }
    layers.push(Layer::linear("fc", 6, 4));
    let net = Network {
        name: "golden-chained".into(),
        layers,
    };
    let g = graph::lower(&net).expect("chained blocks lower");
    assert_eq!(g.residual_adds(), 3);
    assert_eq!(g.weight_nodes(), 8);
    // Global 5x pool before the FC.
    assert_eq!(g.pool_nodes(), 1);
    assert_matches_reference(&net, 2, 33).unwrap();
}

#[test]
fn resnet_tiny_residual_adds_are_bit_exact_against_the_reference() {
    assert_matches_reference(&lrmp::nets::resnet::resnet_tiny(), 4, 99).unwrap();
}

// ----------------------------------------------------------------------
// Malformed graphs: typed errors, not panics or strings
// ----------------------------------------------------------------------

#[test]
fn cyclic_graph_is_a_typed_error() {
    // add#1 and add#2 feed each other — no schedule exists.
    let nodes = vec![
        Node::new(Op::Input { features: 4 }, vec![], false),
        Node::new(Op::Add, vec![NodeId(0), NodeId(2)], false),
        Node::new(Op::Add, vec![NodeId(1), NodeId(1)], false),
        Node::new(Op::Output, vec![NodeId(2)], false),
    ];
    match Graph::compile(nodes) {
        Err(GraphError::Cycle { .. }) => {}
        other => panic!("expected GraphError::Cycle, got {other:?}"),
    }
}

#[test]
fn dangling_input_is_a_typed_error() {
    let nodes = vec![
        Node::new(Op::Input { features: 4 }, vec![], false),
        Node::new(
            Op::MatMul {
                layer: 0,
                in_f: 4,
                out_f: 4,
            },
            vec![NodeId(7)], // node #7 does not exist
            false,
        ),
        Node::new(Op::Output, vec![NodeId(1)], false),
    ];
    match Graph::compile(nodes) {
        Err(GraphError::DanglingInput { node: 1, input: 7 }) => {}
        other => panic!("expected GraphError::DanglingInput, got {other:?}"),
    }
}

#[test]
fn unlowerable_networks_surface_graph_errors_through_supports() {
    // A shape-changing block with no projection cannot lower; the typed
    // GraphError renders into the supports() reason.
    let net = Network {
        name: "bad-block".into(),
        layers: vec![
            Layer::conv("b.0.conv1", 3, 8, 3, 2, 1, 8),
            Layer::conv("b.0.conv2", 8, 8, 3, 1, 1, 4),
        ],
    };
    assert!(matches!(graph::lower(&net), Err(GraphError::Unsupported(_))));
    let reason = SimBackend::supports(&net).unwrap_err();
    assert!(reason.contains("downsample"), "{reason}");
}

// ----------------------------------------------------------------------
// Property: graph execution == reference, any supported net, any threads
// ----------------------------------------------------------------------

/// Generate a random sim-supported network: an MLP chain, a sequential
/// conv chain (with an implied pool before the FC), or a residual stack
/// (identity blocks, optionally a projected stride-2 block).
fn random_supported_net(rng: &mut Rng) -> Network {
    match rng.below(3) {
        0 => {
            let n_layers = rng.int_range(2, 4) as usize;
            let mut dims = Vec::with_capacity(n_layers + 1);
            for _ in 0..=n_layers {
                dims.push(rng.int_range(3, 18) as u64);
            }
            let layers = dims
                .windows(2)
                .enumerate()
                .map(|(i, w)| Layer::linear(&format!("fc{}", i + 1), w[0], w[1]))
                .collect();
            Network {
                name: "prop-mlp".into(),
                layers,
            }
        }
        1 => {
            let hw = rng.int_range(4, 8) as u64;
            let c0 = rng.int_range(1, 4) as u64;
            let c1 = rng.int_range(2, 6) as u64;
            let c2 = rng.int_range(2, 6) as u64;
            let mut layers = vec![
                Layer::conv("conv1", c0, c1, 3, 1, 1, hw),
                Layer::conv("conv2", c1, c2, 3, 1, 1, hw),
            ];
            // Flatten the full grid or pool down to a divisor grid.
            let s = if hw % 2 == 0 && rng.below(2) == 0 {
                hw / 2
            } else {
                hw
            };
            layers.push(Layer::linear("fc", c2 * s * s, rng.int_range(2, 10) as u64));
            Network {
                name: "prop-conv".into(),
                layers,
            }
        }
        _ => {
            let hw = 2 * rng.int_range(2, 4) as u64; // even, 4..=8
            let c = rng.int_range(2, 5) as u64;
            let mut layers = vec![Layer::conv("stem", 3, c, 3, 1, 1, hw)];
            let identity_blocks = rng.int_range(1, 2) as usize;
            for blk in 0..identity_blocks {
                layers.push(Layer::conv(&format!("layer1.{blk}.conv1"), c, c, 3, 1, 1, hw));
                layers.push(Layer::conv(&format!("layer1.{blk}.conv2"), c, c, 3, 1, 1, hw));
            }
            let mut out_c = c;
            if rng.below(2) == 0 {
                // A stride-2 projected block halves the grid.
                let c2 = 2 * c;
                layers.push(Layer::conv("layer2.0.conv1", c, c2, 3, 2, 1, hw));
                layers.push(Layer::conv("layer2.0.conv2", c2, c2, 3, 1, 1, hw / 2));
                layers.push(Layer::conv("layer2.0.downsample", c, c2, 1, 2, 0, hw));
                out_c = c2;
            }
            // Global pool + FC head.
            layers.push(Layer::linear("fc", out_c, rng.int_range(2, 8) as u64));
            Network {
                name: "prop-resnet".into(),
                layers,
            }
        }
    }
}

#[test]
fn prop_graph_execution_matches_reference_across_threads() {
    propcheck::check("graph-vs-reference-bitwise", 12, |rng: &mut Rng| {
        let net = random_supported_net(rng);
        if let Err(e) = SimBackend::supports(&net) {
            return Err(format!("generated net must be supported: {e}"));
        }
        let b = rng.int_range(1, 3) as usize;
        let seed = rng.next_u64();
        assert_matches_reference(&net, b, seed)
    });
}
