//! Facade integration tests that run fully offline (no PJRT artifacts):
//! the search → Deployment → save/load/validate → simulate → serve pipeline
//! over the SQNR surrogate and the deterministic sim serving backend.

use lrmp::api::{ApiError, Deployment, ServeBackend, Session};
use lrmp::coordinator::batcher::BatchPolicy;
use lrmp::replication::Objective;
use std::time::Duration;

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("lrmp-api-tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// A short real search on the paper's MNIST MLP (surrogate accuracy).
fn searched_mlp() -> Deployment {
    Session::new("mlp")
        .expect("mlp is a known benchmark")
        .objective(Objective::Latency)
        .episodes(3)
        .updates_per_episode(1)
        .seed(0xC0FFEE)
        .search()
        .expect("3-episode search must succeed")
}

#[test]
fn session_smoke_search_on_mlp() {
    let dep = searched_mlp();
    assert_eq!(dep.net, "MLP");
    assert_eq!(dep.schema_version, lrmp::api::SCHEMA_VERSION);
    assert_eq!(dep.policy.len(), 5);
    assert_eq!(dep.replication.len(), 5);
    assert!(dep.tiles_used <= dep.n_tiles);
    assert!(dep.replication.iter().all(|&r| r >= 1));
    assert_eq!(dep.provenance.episodes, 3);
    assert_eq!(dep.provenance.seed, 0xC0FFEE);
    assert_eq!(dep.provenance.accuracy_provider, "sqnr-surrogate");
    // The searched design must beat the 8-bit baseline on its objective.
    assert!(
        dep.predicted.latency_improvement() > 1.0,
        "latency improvement {}",
        dep.predicted.latency_improvement()
    );
}

#[test]
fn deployment_roundtrips_through_json_file() {
    let dep = searched_mlp();
    let path = tmp("roundtrip.json");
    dep.save(&path).expect("save");
    let loaded = Deployment::load(&path).expect("load");
    assert_eq!(dep, loaded, "save -> load must be deep-equal");
    // And the loaded artifact still passes cost-model re-validation.
    let cost = loaded.validate().expect("validate");
    assert_eq!(cost.tiles_used, loaded.tiles_used);
}

#[test]
fn validate_rejects_over_budget_artifact() {
    let mut dep = searched_mlp();
    // Tamper: shrink the budget below the plan's demand.
    dep.n_tiles = dep.tiles_used - 1;
    match dep.validate() {
        Err(ApiError::Infeasible { needed, available }) => {
            assert_eq!(needed, dep.tiles_used);
            assert_eq!(available, dep.n_tiles);
        }
        other => panic!("expected Infeasible, got {other:?}"),
    }
}

#[test]
fn validate_rejects_tampered_replication() {
    let mut dep = searched_mlp();
    // Inflate a replication factor: either the tile budget bursts or the
    // recorded tiles/latency no longer match the cost model.
    dep.replication[0] += 500;
    assert!(dep.validate().is_err());
}

#[test]
fn simulate_cross_checks_the_artifact() {
    let dep = searched_mlp();
    let report = Session::simulate(&dep).expect("simulate");
    assert_eq!(report.rows.len(), 5);
    assert!(report.simulated_total_cycles > 0);
    // analytic_cycles is T_l / min(r, W²) — the replication the event
    // simulator can exploit within one inference — so simulated/analytic
    // must sit near 1 for every layer (stage rounding adds a few cycles).
    for row in &report.rows {
        let ratio = row.simulated_cycles as f64 / row.analytic_cycles.max(1.0);
        assert!(
            (0.5..=1.02).contains(&ratio)
                || (row.simulated_cycles as f64) <= row.analytic_cycles + 8.0,
            "{}: simulated {} vs analytic {} (ratio {ratio})",
            row.layer,
            row.simulated_cycles,
            row.analytic_cycles
        );
    }
}

#[test]
fn serve_executes_the_searched_policy_on_the_sim_backend() {
    // mlp-tiny keeps the quantized forward pass cheap in debug builds.
    let dep = Session::new("mlp-tiny")
        .unwrap()
        .episodes(2)
        .updates_per_episode(1)
        .seed(7)
        .search()
        .expect("search");
    let server = Session::serve_with(
        &dep,
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_millis(2),
        },
        ServeBackend::Sim,
    )
    .expect("serve");

    // The served policy is exactly the searched policy (the acceptance
    // criterion of the artifact-centric pipeline).
    assert_eq!(server.policy, dep.policy);
    assert_eq!(server.backend_name, "sim");

    let dim = server.input_dim();
    assert_eq!(dim, 256);
    for i in 0..32 {
        let x: Vec<f32> = (0..dim).map(|j| ((i + j) % 13) as f32 / 13.0).collect();
        let logits = server.infer(x).expect("infer");
        assert_eq!(logits.len(), 10);
    }
    let m = server.snapshot_metrics();
    assert_eq!(m.requests, 32);
    assert!(m.batches >= 1);
    assert_eq!(m.failures, 0);
}

#[test]
fn serve_rejects_wrong_input_dim() {
    let dep = Deployment::from_policy(
        "mlp-tiny",
        &lrmp::arch::ChipConfig::paper_scaled(),
        Objective::Latency,
        lrmp::quant::Policy::baseline(4),
        vec![1; 4],
        None,
    )
    .unwrap();
    let server =
        Session::serve_with(&dep, BatchPolicy::default(), ServeBackend::Sim).unwrap();
    assert!(server.infer(vec![0.0; 3]).is_err());
}

#[test]
fn fixed_policy_deployment_serves_uniform_bits() {
    let dep = Deployment::from_policy(
        "mlp-tiny",
        &lrmp::arch::ChipConfig::paper_scaled(),
        Objective::Throughput,
        lrmp::quant::Policy::uniform(4, 5, 6),
        vec![1; 4],
        None,
    )
    .unwrap();
    assert_eq!(dep.provenance.accuracy_provider, "fixed-policy");
    let server =
        Session::serve_with(&dep, BatchPolicy::default(), ServeBackend::Sim).unwrap();
    assert!(server
        .policy
        .layers
        .iter()
        .all(|l| l.w_bits == 5 && l.a_bits == 6));
}
