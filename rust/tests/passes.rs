//! Pass-pipeline integration: golden legality tests for each production
//! pass (a Pool or Conv with a second consumer must NOT fuse; a dead aux
//! head must be eliminated), arena-reduction checks on the conv
//! benchmarks, and a propcheck property that compiling with passes on vs
//! off yields bitwise-identical logits across worker-thread counts
//! 1/2/4/7 — with `eval_reference` (the unoptimized straight-line
//! executor) as the adversarial comparator for both.

use lrmp::coordinator::InferenceBackend;
use lrmp::nets::{self, Layer, Network};
use lrmp::runtime::graph::{self, Graph, Node, NodeId, Op};
use lrmp::runtime::passes::{self, FuseConvPool, Pass, PassConfig};
use lrmp::runtime::simnet::{SimBackend, SimOptions};
use lrmp::util::propcheck;
use lrmp::util::prng::Rng;

fn bits_of(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn plain_opts() -> SimOptions {
    SimOptions {
        passes: PassConfig::none(),
        ..SimOptions::default()
    }
}

/// Eval `net` with passes on and off at several thread counts; every
/// result must equal the unoptimized straight-line reference bit for
/// bit.
fn assert_passes_equivalent(net: &Network, b: usize, seed: u64) -> Result<(), String> {
    let nl = net.num_layers();
    let reference =
        SimBackend::from_network(net, b, seed).map_err(|e| format!("{}: {e}", net.name))?;
    let dim = reference.input_dim();
    let x: Vec<f32> = (0..b * dim)
        .map(|i| ((i * 17 + 3) % 59) as f32 / 59.0 - 0.2)
        .collect();
    let wb = vec![5.0f32; nl];
    let ab = vec![6.0f32; nl];
    let want = bits_of(&reference.eval_reference(&x, &wb, &ab));
    for threads in [1usize, 2, 4, 7] {
        for passes_on in [true, false] {
            let opts = SimOptions {
                threads: Some(threads),
                passes: if passes_on {
                    PassConfig::default()
                } else {
                    PassConfig::none()
                },
                ..SimOptions::default()
            };
            let mut backend = SimBackend::from_network_cfg(net, b, seed, opts)
                .map_err(|e| format!("{}: {e}", net.name))?;
            let y = backend
                .eval(x.clone(), wb.clone(), ab.clone())
                .map_err(|e| format!("{}: eval failed: {e}", net.name))?;
            if bits_of(&y) != want {
                return Err(format!(
                    "{}: passes={passes_on} diverged from the reference at threads={threads}",
                    net.name
                ));
            }
        }
    }
    Ok(())
}

// ----------------------------------------------------------------------
// Golden: fusion happens where legal and shrinks the arena
// ----------------------------------------------------------------------

#[test]
fn conv_tiny_fused_backend_is_bitwise_equal_and_smaller() {
    let net = nets::conv_tiny();
    let nl = net.num_layers();
    let mut fused = SimBackend::from_network(&net, 2, 5).unwrap();
    let mut plain = SimBackend::from_network_cfg(&net, 2, 5, plain_opts()).unwrap();
    let (sf, sp) = (fused.schedule_summary(), plain.schedule_summary());
    assert_eq!(sf.fused_convs, 1, "{sf:?}");
    assert_eq!(sf.pool_nodes, 0);
    assert_eq!(sp.fused_convs, 0);
    assert_eq!(sp.pool_nodes, 1);
    assert!(
        sf.arena_bytes < sp.arena_bytes,
        "fusion must reduce arena_bytes: {} vs {}",
        sf.arena_bytes,
        sp.arena_bytes
    );
    assert!(sf.arena_bytes_saved > 0);
    let x: Vec<f32> = (0..2 * 192).map(|i| ((i * 11) % 37) as f32 / 37.0 - 0.4).collect();
    let bits = vec![6.0f32; nl];
    let yf = fused.eval(x.clone(), bits.clone(), bits.clone()).unwrap();
    let yp = plain.eval(x.clone(), bits.clone(), bits.clone()).unwrap();
    let yr = fused.eval_reference(&x, &bits, &bits);
    assert_eq!(bits_of(&yf), bits_of(&yp), "fused vs passes-off logits");
    assert_eq!(bits_of(&yf), bits_of(&yr), "fused vs reference logits");
}

#[test]
fn vgg16_fusion_reduces_arena_bytes_at_the_graph_level() {
    // Graph-level (building a vgg16 backend would allocate 138M synthetic
    // weights): the acceptance metric is the per-sample slot-arena floats
    // the liveness pass assigns, which schedule_summary's arena_bytes is
    // built from.
    let net = nets::vgg16();
    let unfused = graph::lower(&net).unwrap();
    let mut nodes = graph::lower_nodes(&net).unwrap();
    let report = passes::run(&mut nodes, &PassConfig::default());
    let fused = Graph::compile(nodes).unwrap();
    assert_eq!(report.rewrites_of("fuse-conv-pool"), 5);
    assert_eq!(fused.fused_convs(), 5);
    assert_eq!(fused.pool_nodes(), 0);
    assert!(
        fused.arena_floats_per_sample() * 4 <= unfused.arena_floats_per_sample() * 3,
        "vgg16 fusion must cut the slot arena by >= 25%: {} -> {}",
        unfused.arena_floats_per_sample(),
        fused.arena_floats_per_sample()
    );
}

// ----------------------------------------------------------------------
// Golden: fusion legality — second consumers veto the fuse
// ----------------------------------------------------------------------

/// input(3ch 4x4, 48 features) -> conv(3->4, k3 s1 p1) -> pool(2x).
/// Returns the node list plus the conv and pool ids so callers can
/// attach consumers.
fn conv_pool_prefix() -> (Vec<Node>, NodeId, NodeId) {
    let nodes = vec![
        Node::new(Op::Input { features: 48 }, vec![], false),
        Node::new(
            Op::Conv {
                layer: 0,
                geom: lrmp::runtime::gemm::ConvGeom {
                    in_c: 3,
                    out_c: 4,
                    kernel: 3,
                    stride: 1,
                    padding: 1,
                    in_hw: 4,
                    out_hw: 4,
                },
                pool: None,
            },
            vec![NodeId(0)],
            true,
        ),
        Node::new(
            Op::Pool {
                channels: 4,
                hw: 4,
                factor: 2,
            },
            vec![NodeId(1)],
            false,
        ),
    ];
    (nodes, NodeId(1), NodeId(2))
}

fn matmul(layer: usize, in_f: usize, out_f: usize, from: NodeId) -> Node {
    Node::new(Op::MatMul { layer, in_f, out_f }, vec![from], false)
}

#[test]
fn pool_with_a_second_consumer_must_not_fuse() {
    // The pool feeds TWO MatMul heads whose sum feeds Output: legal
    // graph, but the conservative fusion rule must leave it alone.
    let (mut nodes, _conv, pool) = conv_pool_prefix();
    nodes.push(matmul(1, 16, 4, pool)); // #3
    nodes.push(matmul(2, 16, 4, pool)); // #4
    nodes.push(Node::new(Op::Add, vec![NodeId(3), NodeId(4)], false)); // #5
    nodes.push(Node::new(Op::Output, vec![NodeId(5)], false)); // #6
    let before = nodes.len();
    let fused = FuseConvPool.run(&mut nodes);
    assert_eq!(fused, 0, "a Pool with a second consumer must NOT fuse");
    assert_eq!(nodes.len(), before);
    let g = Graph::compile(nodes).unwrap();
    assert_eq!(g.pool_nodes(), 1);
    assert_eq!(g.fused_convs(), 0);
}

#[test]
fn conv_with_a_second_consumer_must_not_fuse() {
    // The conv's full-resolution grid is read by the pool AND flattened
    // by a second head: fusing would destroy the second reader's input.
    let (mut nodes, conv, pool) = conv_pool_prefix();
    nodes.push(matmul(1, 16, 4, pool)); // #3: pooled head
    nodes.push(matmul(2, 64, 4, conv)); // #4: full-grid head
    nodes.push(Node::new(Op::Add, vec![NodeId(3), NodeId(4)], false)); // #5
    nodes.push(Node::new(Op::Output, vec![NodeId(5)], false)); // #6
    let fused = FuseConvPool.run(&mut nodes);
    assert_eq!(fused, 0, "a Conv with a second consumer must NOT fuse");
    let g = Graph::compile(nodes).unwrap();
    assert_eq!(g.pool_nodes(), 1);
    assert_eq!(g.fused_convs(), 0);
}

#[test]
fn single_consumer_chain_fuses_and_compiles_to_the_pooled_shape() {
    let (mut nodes, _conv, pool) = conv_pool_prefix();
    nodes.push(matmul(1, 16, 4, pool)); // #3
    nodes.push(Node::new(Op::Output, vec![NodeId(3)], false)); // #4
    let fused = FuseConvPool.run(&mut nodes);
    assert_eq!(fused, 1);
    let g = Graph::compile(nodes).unwrap();
    assert_eq!(g.pool_nodes(), 0);
    assert_eq!(g.fused_convs(), 1);
    // The fused conv's output is the pooled 4ch 2x2 grid.
    let conv_id = (0..g.num_nodes())
        .map(NodeId)
        .find(|&id| matches!(g.node(id).op, Op::Conv { .. }))
        .unwrap();
    assert_eq!(g.out_features(conv_id), 4 * 2 * 2);
}

// ----------------------------------------------------------------------
// Golden: dead-node elimination
// ----------------------------------------------------------------------

#[test]
fn dead_aux_head_is_eliminated() {
    // input -> m0 -> m1 -> Output, plus an aux head m2 reading m0 that
    // nothing consumes: the pass must remove exactly the aux head.
    let nodes = vec![
        Node::new(Op::Input { features: 8 }, vec![], false),
        matmul(0, 8, 8, NodeId(0)),
        matmul(1, 8, 4, NodeId(1)),
        matmul(2, 8, 3, NodeId(1)), // dead aux head off m0
        Node::new(Op::Output, vec![NodeId(2)], false),
    ];
    let mut optimized = nodes.clone();
    let report = passes::run(&mut optimized, &PassConfig::default());
    assert_eq!(report.rewrites_of("dead-node-elim"), 1);
    assert_eq!(report.nodes_before, 5);
    assert_eq!(report.nodes_after, 4);
    let g = Graph::compile(optimized).unwrap();
    assert_eq!(g.weight_nodes(), 2, "only the live chain survives");
    assert_eq!(g.out_features(g.output()), 4);
    // The unoptimized list still compiles too (the aux head is legal,
    // just wasted work) — and costs an extra arena slot.
    let g0 = Graph::compile(nodes).unwrap();
    assert_eq!(g0.weight_nodes(), 3);
    assert!(g.arena_floats_per_sample() <= g0.arena_floats_per_sample());
}

#[test]
fn dead_second_consumer_unblocks_fusion() {
    // The pool's second consumer is a dead head: dead-node elimination
    // runs first, so the full pipeline still fuses the conv+pool chain.
    let (mut nodes, _conv, pool) = conv_pool_prefix();
    nodes.push(matmul(1, 16, 4, pool)); // #3: live head
    nodes.push(matmul(2, 16, 4, pool)); // #4: dead head (no consumers)
    nodes.push(Node::new(Op::Output, vec![NodeId(3)], false)); // #5
    let report = passes::run(&mut nodes, &PassConfig::default());
    assert_eq!(report.rewrites_of("dead-node-elim"), 1);
    assert_eq!(report.rewrites_of("fuse-conv-pool"), 1);
    let g = Graph::compile(nodes).unwrap();
    assert_eq!(g.pool_nodes(), 0);
    assert_eq!(g.fused_convs(), 1);
}

// ----------------------------------------------------------------------
// Property: passes on vs off, bitwise, across thread counts
// ----------------------------------------------------------------------

/// Random sim-supported nets biased toward pool-bearing conv chains
/// (the fusion pass's habitat), plus MLPs (pipeline no-op) and residual
/// stacks (whose trailing global pool follows an Add and must not fuse).
fn random_net(rng: &mut Rng) -> Network {
    match rng.below(4) {
        0 => {
            let n_layers = rng.int_range(2, 4) as usize;
            let mut dims = Vec::with_capacity(n_layers + 1);
            for _ in 0..=n_layers {
                dims.push(rng.int_range(3, 14) as u64);
            }
            let layers = dims
                .windows(2)
                .enumerate()
                .map(|(i, w)| Layer::linear(&format!("fc{}", i + 1), w[0], w[1]))
                .collect();
            Network {
                name: "prop-mlp".into(),
                layers,
            }
        }
        1 => {
            // conv -> (pool) -> conv -> (pool) -> fc: the mid pool fuses
            // into conv1, the tail pool into conv2 when present.
            let hw = 2 * rng.int_range(2, 4) as u64; // 4..=8, even
            let c1 = rng.int_range(2, 5) as u64;
            let c2 = rng.int_range(2, 5) as u64;
            let mid_pool = rng.below(2) == 0;
            let hw2 = if mid_pool { hw / 2 } else { hw };
            let tail = if hw2 % 2 == 0 && rng.below(2) == 0 {
                hw2 / 2
            } else {
                hw2
            };
            let layers = vec![
                Layer::conv("conv1", 3, c1, 3, 1, 1, hw),
                Layer::conv("conv2", c1, c2, 3, 1, 1, hw2),
                Layer::linear("fc", c2 * tail * tail, rng.int_range(2, 8) as u64),
            ];
            Network {
                name: "prop-conv-pool".into(),
                layers,
            }
        }
        2 => {
            // Conv chain with no pooling at all (fusion must be a no-op).
            let hw = rng.int_range(4, 7) as u64;
            let c = rng.int_range(2, 5) as u64;
            let layers = vec![
                Layer::conv("conv1", 3, c, 3, 1, 1, hw),
                Layer::conv("conv2", c, c, 3, 1, 1, hw),
                Layer::linear("fc", c * hw * hw, rng.int_range(2, 6) as u64),
            ];
            Network {
                name: "prop-conv-flat".into(),
                layers,
            }
        }
        _ => {
            // Residual stack: identity blocks + global pool + FC head.
            let hw = 2 * rng.int_range(2, 4) as u64;
            let c = rng.int_range(2, 5) as u64;
            let mut layers = vec![Layer::conv("stem", 3, c, 3, 1, 1, hw)];
            for blk in 0..rng.int_range(1, 2) {
                layers.push(Layer::conv(&format!("layer1.{blk}.conv1"), c, c, 3, 1, 1, hw));
                layers.push(Layer::conv(&format!("layer1.{blk}.conv2"), c, c, 3, 1, 1, hw));
            }
            layers.push(Layer::linear("fc", c, rng.int_range(2, 6) as u64));
            Network {
                name: "prop-resnet".into(),
                layers,
            }
        }
    }
}

#[test]
fn prop_passes_on_vs_off_logits_bitwise_across_threads() {
    propcheck::check("passes-on-vs-off-bitwise", 12, |rng: &mut Rng| {
        let net = random_net(rng);
        if let Err(e) = SimBackend::supports(&net) {
            return Err(format!("generated net must be supported: {e}"));
        }
        let b = rng.int_range(1, 3) as usize;
        let seed = rng.next_u64();
        assert_passes_equivalent(&net, b, seed)
    });
}

#[test]
fn benchmark_nets_pass_equivalence() {
    for net in [nets::conv_tiny(), nets::resnet::resnet_tiny(), nets::mlp_tiny()] {
        assert_passes_equivalent(&net, 2, 77).unwrap();
    }
}
