//! End-to-end integration: the full LRMP search with the *live* accuracy
//! path — DDPG episodes whose rewards come from quantized inference executed
//! through PJRT artifacts (rust → XLA → Pallas-authored HLO), with LP
//! replication on the cost model — driven through the `lrmp::api` facade.
//! Requires `make artifacts` (skipped with a clear message otherwise).

use lrmp::api::Session;
use lrmp::replication::Objective;
use lrmp::runtime;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = runtime::default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        None
    }
}

#[test]
fn live_search_improves_latency_at_near_iso_accuracy() {
    let Some(dir) = artifacts() else { return };
    // The live path uses the scaled MLP geometry that matches the artifacts.
    let (dep, res) = Session::new("mlp-tiny")
        .expect("mlp-tiny is a known benchmark")
        .objective(Objective::Latency)
        .episodes(10)
        .updates_per_episode(3)
        .budget(0.5, 0.35)
        .seed(0xBEEF)
        .samples(512)
        .live(true)
        .finetune_steps(25)
        .artifacts_dir(dir)
        .search_detailed()
        .expect("search");

    // Performance: the budget forces ≥ 2× latency improvement.
    assert!(
        res.latency_improvement() >= 2.0,
        "latency improvement {}",
        res.latency_improvement()
    );
    // Area: never exceeds the 8-bit baseline tile count (paper's constraint).
    assert!(dep.tiles_used <= dep.n_tiles);
    // Accuracy: near iso-accuracy after finetuning (paper: <1% loss; allow
    // 5 points on this tiny budget of episodes/steps).
    assert!(
        res.finetuned_accuracy >= res.baseline_accuracy - 0.05,
        "accuracy {} vs baseline {}",
        res.finetuned_accuracy,
        res.baseline_accuracy
    );
    // The trajectory was actually explored.
    assert_eq!(res.trajectory.len(), 10);
    assert!(res.trajectory.iter().any(|e| e.feasible));

    // The artifact records the live provider and validates cleanly.
    assert_eq!(dep.provenance.accuracy_provider, "live-pjrt");
    dep.validate().expect("searched artifact must validate");
}
