//! Integration tests over the PJRT runtime + artifacts: the full
//! rust-loads-jax/pallas-HLO path. Requires `make artifacts` (skipped with a
//! clear message otherwise).

use lrmp::accuracy::Evaluator;
use lrmp::quant::Policy;
use lrmp::runtime::{self, engine::Engine};
use lrmp::util::prng::Rng;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = runtime::default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        None
    }
}

#[test]
fn crossbar_demo_bit_exact_equals_fast() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::start(dir).expect("engine start");
    let (b, r, n) = engine.demo_shape;
    let mut rng = Rng::new(42);
    let x: Vec<f32> = (0..b * r).map(|_| rng.f64() as f32).collect();
    let w: Vec<f32> = (0..r * n).map(|_| rng.normal() as f32).collect();
    for (wb, ab) in [(8.0, 8.0), (5.0, 6.0), (2.0, 2.0), (3.0, 7.0)] {
        let (exact, fast) = engine
            .crossbar_demo(x.clone(), w.clone(), wb, ab)
            .expect("demo run");
        assert_eq!(exact.len(), b * n);
        assert_eq!(
            exact, fast,
            "bit-exact and fast crossbar kernels diverged at w={wb} a={ab}"
        );
        // Non-trivial output.
        assert!(exact.iter().any(|&v| v != 0.0));
    }
}

#[test]
fn quantized_accuracy_through_pjrt() {
    let Some(dir) = artifacts() else { return };
    let ev = Evaluator::new(&dir).expect("evaluator");
    let l = ev.engine.num_layers;

    let acc8 = ev.accuracy(&Policy::uniform(l, 8, 8), 512).expect("acc 8/8");
    assert!(
        acc8 > 0.85,
        "8/8 accuracy {acc8} too far below the build-time value"
    );

    let acc2 = ev.accuracy(&Policy::uniform(l, 2, 2), 512).expect("acc 2/2");
    assert!(
        acc2 < acc8 - 0.2,
        "2/2 accuracy {acc2} should collapse vs 8/8 {acc8}"
    );
}

#[test]
fn finetune_recovers_low_bit_accuracy() {
    let Some(dir) = artifacts() else { return };
    let ev = Evaluator::new(&dir).expect("evaluator");
    let l = ev.engine.num_layers;
    let policy = Policy::uniform(l, 3, 4);

    ev.reset().unwrap();
    let before = ev.accuracy(&policy, 512).unwrap();
    let losses = ev.finetune(&policy, 30, 0.05, 7).unwrap();
    let after = ev.accuracy(&policy, 512).unwrap();
    ev.reset().unwrap();
    let reset_acc = ev.accuracy(&policy, 512).unwrap();

    assert!(
        after >= before - 0.02,
        "finetuning hurt: {before} -> {after} (losses {losses:?})"
    );
    assert!(
        (reset_acc - before).abs() < 0.03,
        "reset_params failed to restore: {before} vs {reset_acc}"
    );
}

#[test]
fn eval_rejects_wrong_batch() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::start(dir).expect("engine start");
    let err = engine.eval(vec![0.0; 3], vec![8.0; 4], vec![8.0; 4]);
    assert!(err.is_err());
}
