//! Serving-path integration: the L3 coordinator (dynamic batcher + request
//! router) over the PJRT engine — concurrent clients, correctness of routed
//! logits, and batching metrics. Requires `make artifacts`.

use lrmp::coordinator::batcher::BatchPolicy;
use lrmp::coordinator::Server;
use lrmp::quant::Policy;
use lrmp::runtime::{self, engine::Engine};
use std::sync::Arc;
use std::time::Duration;

fn artifacts() -> Option<std::path::PathBuf> {
    let dir = runtime::default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir:?} — run `make artifacts`");
        None
    }
}

fn load_test_set(dir: &std::path::Path, n: usize) -> (Vec<Vec<f32>>, Vec<i32>, usize) {
    let manifest = runtime::Manifest::load(dir).unwrap();
    let x = manifest.tensor(&manifest.dataset.x_test).unwrap();
    let y = manifest.tensor(&manifest.dataset.y_test).unwrap();
    let dim = x.dims[1];
    let xs = x.as_f32().unwrap();
    let samples = (0..n.min(x.dims[0]))
        .map(|i| xs[i * dim..(i + 1) * dim].to_vec())
        .collect();
    (samples, y.as_i32().unwrap()[..n].to_vec(), dim)
}

#[test]
fn batched_serving_routes_correct_logits() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::start(dir.clone()).expect("engine");
    let nl = engine.num_layers;
    let server = Arc::new(Server::start(
        engine,
        &Policy::baseline(nl),
        BatchPolicy {
            max_batch: 64,
            max_wait: Duration::from_millis(4),
        },
    ));

    let (samples, labels, _dim) = load_test_set(&dir, 192);

    // Concurrent clients hammer the server; each checks its own answer.
    let mut handles = Vec::new();
    for client in 0..4 {
        let server = Arc::clone(&server);
        let samples = samples.clone();
        let labels = labels.clone();
        handles.push(std::thread::spawn(move || {
            let mut correct = 0usize;
            let mut count = 0usize;
            for i in (client..samples.len()).step_by(4) {
                let logits = server.infer(samples[i].clone()).expect("infer");
                assert_eq!(logits.len(), 10);
                let pred = logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0 as i32;
                correct += usize::from(pred == labels[i]);
                count += 1;
            }
            (correct, count)
        }));
    }
    let (mut correct, mut count) = (0usize, 0usize);
    for h in handles {
        let (c, n) = h.join().unwrap();
        correct += c;
        count += n;
    }
    assert_eq!(count, 192);
    let acc = correct as f64 / count as f64;
    assert!(acc > 0.85, "served accuracy {acc} suspiciously low");

    let m = server.snapshot_metrics();
    assert_eq!(m.requests, 192);
    assert_eq!(m.failures, 0);
    assert!(m.batches >= 3, "requests should ride shared batches");
    assert!(
        (m.batches as usize) < count,
        "batching must coalesce requests ({} batches / {count} requests)",
        m.batches
    );
    assert!(m.mean_fill() > 0.0 && m.mean_fill() <= 1.0);
    assert!(m.latency_p(50.0) > 0.0);
}

#[test]
fn server_rejects_wrong_dimension() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::start(dir).expect("engine");
    let nl = engine.num_layers;
    let server = Server::start(engine, &Policy::baseline(nl), BatchPolicy::default());
    assert!(server.infer(vec![0.0; 3]).is_err());
}

#[test]
fn async_requests_complete() {
    let Some(dir) = artifacts() else { return };
    let engine = Engine::start(dir.clone()).expect("engine");
    let nl = engine.num_layers;
    let server = Server::start(engine, &Policy::uniform(nl, 5, 6), BatchPolicy::default());
    let (samples, _, _) = load_test_set(&dir, 32);
    let rxs: Vec<_> = samples
        .iter()
        .map(|s| server.infer_async(s.clone()).unwrap())
        .collect();
    for rx in rxs {
        let logits = rx.recv().unwrap().unwrap();
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|v| v.is_finite()));
    }
}
