//! Integration tests of the multi-deployment serving front-end (tier-2,
//! pure rust, no artifacts): concurrent submitters driving two routes
//! through **one shared worker pool**, bitwise routed-vs-direct logits
//! equality, poison isolation when one route's backend panics, and the
//! weighted A/B + canary promote/rollback lifecycle.

use lrmp::coordinator::batcher::BatchPolicy;
use lrmp::coordinator::{InferenceBackend, Server};
use lrmp::nets;
use lrmp::quant::Policy;
use lrmp::replication::Objective;
use lrmp::runtime::pool::WorkerPool;
use lrmp::runtime::simnet::{SimBackend, SimOptions};
use lrmp::serve::{
    CanarySpec, DeploymentSource, MultiServer, RouteSpec, RoutesConfig, CANARY, INCUMBENT,
};
use std::sync::Arc;
use std::time::Duration;

const THREADS: usize = 2;

fn sim_opts() -> SimOptions {
    SimOptions {
        threads: Some(THREADS),
        ..SimOptions::default()
    }
}

fn serve_opts() -> lrmp::api::ServeOptions {
    lrmp::api::ServeOptions {
        threads: Some(THREADS),
        ..lrmp::api::ServeOptions::default()
    }
}

/// One-per-batch batching: every request rides alone in a zero-padded
/// batch, which makes routed logits bitwise comparable to a direct eval
/// (activation quantization scales per tensor over the whole batch, so
/// batch composition is part of the numeric contract).
fn solo_batches() -> BatchPolicy {
    BatchPolicy {
        max_batch: 1,
        max_wait: Duration::from_millis(2),
    }
}

fn probe(dim: usize, tag: usize) -> Vec<f32> {
    (0..dim)
        .map(|j| ((j * 7 + tag * 13) % 29) as f32 / 29.0 - 0.4)
        .collect()
}

/// Ground truth for a solo request: row 0 of a direct eval of the same
/// zero-padded batch on a freshly built backend (same net/seed/batch).
fn direct_solo(
    net_name: &str,
    eval_batch: usize,
    seed: u64,
    wb: u32,
    ab: u32,
    x: &[f32],
) -> Vec<f32> {
    let net = nets::by_name(net_name).unwrap();
    let mut backend = SimBackend::from_network_cfg(&net, eval_batch, seed, sim_opts()).unwrap();
    let dim = backend.input_dim();
    assert_eq!(x.len(), dim);
    let mut padded = vec![0f32; eval_batch * dim];
    padded[..dim].copy_from_slice(x);
    let nl = backend.num_layers();
    let logits = backend
        .eval(padded, vec![wb as f32; nl], vec![ab as f32; nl])
        .unwrap();
    logits[..backend.num_classes()].to_vec()
}

#[test]
fn concurrent_submitters_two_routes_one_pool_no_mixing() {
    let pool = Arc::new(WorkerPool::new(THREADS));
    let net_a = nets::by_name("mlp-tiny").unwrap();
    let net_b = nets::by_name("conv-tiny").unwrap();
    let backend_a =
        SimBackend::from_network_shared(&net_a, 4, 7, sim_opts(), Arc::clone(&pool)).unwrap();
    let backend_b =
        SimBackend::from_network_shared(&net_b, 2, 9, sim_opts(), Arc::clone(&pool)).unwrap();
    let server_a = Arc::new(Server::start(
        backend_a,
        &Policy::uniform(net_a.num_layers(), 8, 8),
        solo_batches(),
    ));
    let server_b = Arc::new(Server::start(
        backend_b,
        &Policy::uniform(net_b.num_layers(), 6, 6),
        solo_batches(),
    ));

    // Bitwise expected logits per (route, probe tag), computed on private
    // backends before any traffic flows.
    const TAGS: usize = 4;
    let dim_a = server_a.input_dim();
    let dim_b = server_b.input_dim();
    let expect_a: Vec<Vec<f32>> = (0..TAGS)
        .map(|t| direct_solo("mlp-tiny", 4, 7, 8, 8, &probe(dim_a, t)))
        .collect();
    let expect_b: Vec<Vec<f32>> = (0..TAGS)
        .map(|t| direct_solo("conv-tiny", 2, 9, 6, 6, &probe(dim_b, t)))
        .collect();

    // N client threads interleaving both routes through the one pool. Any
    // cross-route result mixing breaks the bitwise assertions (the two
    // nets do not even share input/output shapes).
    const CLIENTS: usize = 4;
    const PER_ROUTE: usize = 8; // per client
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let (sa, sb) = (Arc::clone(&server_a), Arc::clone(&server_b));
        let (ea, eb) = (expect_a.clone(), expect_b.clone());
        handles.push(std::thread::spawn(move || {
            for i in 0..PER_ROUTE {
                let tag = (c + i) % TAGS;
                let ya = sa.infer(probe(dim_a, tag)).unwrap();
                assert_eq!(ya, ea[tag], "route A logits diverged (client {c}, tag {tag})");
                let yb = sb.infer(probe(dim_b, tag)).unwrap();
                assert_eq!(yb, eb[tag], "route B logits diverged (client {c}, tag {tag})");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    let (ma, mb) = (server_a.snapshot_metrics(), server_b.snapshot_metrics());
    assert_eq!(ma.requests, (CLIENTS * PER_ROUTE) as u64);
    assert_eq!(mb.requests, (CLIENTS * PER_ROUTE) as u64);
    assert_eq!(ma.failures, 0);
    assert_eq!(mb.failures, 0);
    assert!(ma.latency_p(99.0) > 0.0);
    assert!(mb.latency_p(99.0) > 0.0);
}

/// A backend whose every eval poisons a shared-pool job. Models a faulty
/// route sharing the pool with healthy ones.
struct PanicBackend {
    pool: Arc<WorkerPool>,
}

impl InferenceBackend for PanicBackend {
    fn backend_name(&self) -> &'static str {
        "panic-test"
    }
    fn num_layers(&self) -> usize {
        1
    }
    fn input_dim(&self) -> usize {
        8
    }
    fn num_classes(&self) -> usize {
        2
    }
    fn eval_batch(&self) -> usize {
        1
    }
    fn eval(
        &mut self,
        _x: Vec<f32>,
        _wb: Vec<f32>,
        _ab: Vec<f32>,
    ) -> anyhow::Result<Vec<f32>> {
        self.pool
            .try_run(2, |_| panic!("injected route fault"))
            .map_err(|e| anyhow::anyhow!("pool job failed: {e:?}"))?;
        unreachable!("the injected fault always poisons the job")
    }
}

#[test]
fn poisoned_route_does_not_contaminate_its_pool_neighbor() {
    let pool = Arc::new(WorkerPool::new(THREADS));
    let net = nets::by_name("mlp-tiny").unwrap();
    let good_backend =
        SimBackend::from_network_shared(&net, 4, 7, sim_opts(), Arc::clone(&pool)).unwrap();
    let good = Arc::new(Server::start(
        good_backend,
        &Policy::uniform(net.num_layers(), 8, 8),
        solo_batches(),
    ));
    let bad = Arc::new(Server::start(
        PanicBackend {
            pool: Arc::clone(&pool),
        },
        &Policy::uniform(1, 8, 8),
        solo_batches(),
    ));

    let dim = good.input_dim();
    let expected = direct_solo("mlp-tiny", 4, 7, 8, 8, &probe(dim, 0));

    const N: usize = 8;
    let bad_driver = {
        let bad = Arc::clone(&bad);
        std::thread::spawn(move || {
            for _ in 0..N {
                let err = bad.infer(vec![0.5; 8]).unwrap_err();
                assert!(err.to_string().contains("batch failed"), "{err:#}");
            }
        })
    };
    let good_driver = {
        let good = Arc::clone(&good);
        let expected = expected.clone();
        std::thread::spawn(move || {
            for _ in 0..N {
                // Healthy route keeps serving bitwise-correct logits while
                // the neighbor poisons job after job on the same pool.
                assert_eq!(good.infer(probe(dim, 0)).unwrap(), expected);
            }
        })
    };
    bad_driver.join().unwrap();
    good_driver.join().unwrap();

    let (mg, mb) = (good.snapshot_metrics(), bad.snapshot_metrics());
    assert_eq!(mg.requests, N as u64);
    assert_eq!(mg.failures, 0);
    assert_eq!(mb.requests, 0, "failed requests must not count as served");
    assert_eq!(mb.failures, N as u64);

    // And the pool itself stays healthy for direct use.
    let expected_after = direct_solo("mlp-tiny", 4, 7, 8, 8, &probe(dim, 1));
    assert_eq!(good.infer(probe(dim, 1)).unwrap(), expected_after);
}

fn ab_config() -> RoutesConfig {
    RoutesConfig {
        routes: vec![
            RouteSpec {
                name: "mlp".into(),
                weight: 3.0,
                source: DeploymentSource::Uniform {
                    net: "mlp-tiny".into(),
                    objective: Objective::Latency,
                    w_bits: 8,
                    a_bits: 8,
                },
                max_batch: Some(1),
                deadline_ms: Some(1),
                eval_batch: Some(4),
                canary: Some(CanarySpec {
                    source: DeploymentSource::Uniform {
                        net: "mlp-tiny".into(),
                        objective: Objective::Latency,
                        w_bits: 5,
                        a_bits: 6,
                    },
                    fraction: 0.25,
                }),
            },
            RouteSpec {
                name: "conv".into(),
                weight: 1.0,
                source: DeploymentSource::Uniform {
                    net: "conv-tiny".into(),
                    objective: Objective::Latency,
                    w_bits: 6,
                    a_bits: 6,
                },
                max_batch: Some(1),
                deadline_ms: Some(1),
                eval_batch: Some(2),
                canary: None,
            },
        ],
    }
}

#[test]
fn multiserver_ab_split_is_exact_and_bitwise_correct() {
    let ms = MultiServer::start(&ab_config(), serve_opts()).unwrap();
    let dim = ms.input_dim("mlp").unwrap();

    // Uniform inline sources carry provenance seed 0 (Deployment::from_policy).
    let exp_inc = direct_solo("mlp-tiny", 4, 0, 8, 8, &probe(dim, 0));
    let exp_can = direct_solo("mlp-tiny", 4, 0, 5, 6, &probe(dim, 0));
    assert_ne!(exp_inc, exp_can, "5/6-bit canary must change the logits");

    // Weighted routing: every response must be bitwise one of the two
    // variants' expected logits; the split must be exactly 3:1 over 32.
    let mut canary_hits = 0u64;
    for _ in 0..32 {
        let y = ms.infer("mlp", probe(dim, 0)).unwrap();
        if y == exp_can {
            canary_hits += 1;
        } else {
            assert_eq!(y, exp_inc, "response matches neither variant");
        }
    }
    assert_eq!(canary_hits, 8, "0.25 canary fraction must be exact over 32");
    let report = ms.route_report("mlp").unwrap();
    let routed: Vec<u64> = report.variants.iter().map(|v| v.routed).collect();
    assert_eq!(routed, vec![24, 8]);

    // Pinned verification traffic: bitwise per variant, on both routes.
    assert_eq!(ms.infer_on("mlp", INCUMBENT, probe(dim, 0)).unwrap(), exp_inc);
    assert_eq!(ms.infer_on("mlp", CANARY, probe(dim, 0)).unwrap(), exp_can);
    let cdim = ms.input_dim("conv").unwrap();
    let exp_conv = direct_solo("conv-tiny", 2, 0, 6, 6, &probe(cdim, 1));
    assert_eq!(ms.infer_on("conv", INCUMBENT, probe(cdim, 1)).unwrap(), exp_conv);

    // Snapshot carries per-route per-variant percentiles for everything
    // that served traffic.
    let j = ms.snapshot_json();
    assert_eq!(j.get("kind").as_str(), Some("lrmp-serve-metrics"));
    for route in j.get("routes").as_arr().unwrap() {
        for v in route.get("variants").as_arr().unwrap() {
            let m = v.get("metrics");
            if m.get("requests").as_u64().unwrap() > 0 {
                assert!(m.get("p99_s").as_f64().unwrap() > 0.0);
            }
        }
    }
}

#[test]
fn canary_promotion_and_rollback_lifecycle() {
    let dim;
    // Promotion: the canary wins and takes all traffic.
    {
        let ms = MultiServer::start(&ab_config(), serve_opts()).unwrap();
        dim = ms.input_dim("mlp").unwrap();
        let exp_can = direct_solo("mlp-tiny", 4, 0, 5, 6, &probe(dim, 2));
        ms.promote("mlp", CANARY).unwrap();
        for _ in 0..4 {
            assert_eq!(ms.infer("mlp", probe(dim, 2)).unwrap(), exp_can);
        }
        let report = ms.route_report("mlp").unwrap();
        assert_eq!(report.variants.len(), 1);
        assert_eq!(report.variants[0].label, CANARY);
        assert!(ms.infer_on("mlp", INCUMBENT, probe(dim, 2)).is_err());
    }
    // Rollback: the canary loses and is removed; the incumbent keeps
    // serving, and the last variant can never be removed.
    {
        let ms = MultiServer::start(&ab_config(), serve_opts()).unwrap();
        let exp_inc = direct_solo("mlp-tiny", 4, 0, 8, 8, &probe(dim, 2));
        ms.rollback("mlp", CANARY).unwrap();
        for _ in 0..4 {
            assert_eq!(ms.infer("mlp", probe(dim, 2)).unwrap(), exp_inc);
        }
        assert!(ms.rollback("mlp", INCUMBENT).is_err());
        assert!(ms.infer_on("mlp", CANARY, probe(dim, 2)).is_err());
    }
}
