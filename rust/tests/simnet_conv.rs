//! Conv and residual sim-backend integration (fully offline, no PJRT
//! artifacts): sequential conv networks and residual ResNets serve
//! through the batching coordinator via the graph IR (im2col + the pooled
//! matmul kernel), vgg16 and resnet artifacts are servable, and
//! topologies that cannot lower surface as typed `ApiError`s.

use lrmp::api::{ApiError, Deployment, ServeBackend, ServeOptions, Session};
use lrmp::coordinator::batcher::BatchPolicy;
use lrmp::nets;
use lrmp::quant::Policy;
use lrmp::replication::Objective;
use lrmp::runtime::simnet::SimBackend;
use std::time::Duration;

fn fixed_dep(net: &str) -> Deployment {
    let nl = nets::by_name(net).unwrap().num_layers();
    Deployment::from_policy(
        net,
        &lrmp::arch::ChipConfig::paper_scaled(),
        Objective::Latency,
        Policy::uniform(nl, 6, 6),
        vec![1; nl],
        None,
    )
    .unwrap()
}

#[test]
fn conv_tiny_serves_offline_through_the_coordinator() {
    let dep = fixed_dep("conv-tiny");
    let server = Session::serve_with(
        &dep,
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        },
        ServeBackend::Sim,
    )
    .expect("conv-tiny must be sim-servable");
    assert_eq!(server.backend_name, "sim");
    assert_eq!(server.policy, dep.policy);
    assert_eq!(server.input_dim(), 3 * 8 * 8);
    for i in 0..12 {
        let x: Vec<f32> = (0..192).map(|j| ((i + j) % 11) as f32 / 11.0).collect();
        let logits = server.infer(x).expect("infer");
        assert_eq!(logits.len(), 10);
        assert!(logits.iter().all(|v| v.is_finite()));
    }
    let m = server.snapshot_metrics();
    assert_eq!(m.requests, 12);
    assert_eq!(m.failures, 0);
}

#[test]
fn conv_serving_is_deterministic_across_servers() {
    let dep = fixed_dep("conv-tiny");
    let x: Vec<f32> = (0..192).map(|j| (j % 7) as f32 / 7.0).collect();
    let mut answers = Vec::new();
    for _ in 0..2 {
        let server =
            Session::serve_with(&dep, BatchPolicy::default(), ServeBackend::Sim).unwrap();
        answers.push(server.infer(x.clone()).unwrap());
    }
    assert_eq!(answers[0], answers[1], "same artifact, same logits");
}

#[test]
fn vgg16_deployment_is_servable_offline() {
    // Construction only: a debug-mode VGG-16 forward is far too slow for
    // the test suite, but standing the server up proves the artifact
    // validates, the sim backend accepts the topology (13 convs with
    // inter-stage pooling + 3 FC layers), and the coordinator wires up.
    let dep = fixed_dep("vgg16");
    assert!(SimBackend::supports(&nets::vgg16()).is_ok());
    let opts = ServeOptions {
        eval_batch: Some(1),
        ..ServeOptions::default()
    };
    let server = Session::serve_opts(&dep, BatchPolicy::default(), ServeBackend::Sim, opts)
        .expect("vgg16 must be sim-servable");
    assert_eq!(server.backend_name, "sim");
    assert_eq!(server.input_dim(), 3 * 224 * 224);
    assert_eq!(server.policy.len(), 16);
}

#[test]
fn serving_is_invariant_across_kernel_thread_counts() {
    // The pooled kernels must not let the thread split leak into the
    // logits: the same request served through 1-, 2- and 7-thread pools
    // (7 exceeds the eval batch) answers bit-for-bit identically.
    let dep = fixed_dep("conv-tiny");
    let x: Vec<f32> = (0..192).map(|j| ((j * 5) % 13) as f32 / 13.0).collect();
    let mut answers: Vec<Vec<f32>> = Vec::new();
    for threads in [1usize, 2, 7] {
        let opts = ServeOptions {
            threads: Some(threads),
            ..ServeOptions::default()
        };
        let server =
            Session::serve_opts(&dep, BatchPolicy::default(), ServeBackend::Sim, opts).unwrap();
        assert_eq!(server.exec_threads, threads);
        answers.push(server.infer(x.clone()).unwrap());
    }
    assert_eq!(answers[0], answers[1], "1 vs 2 threads");
    assert_eq!(answers[0], answers[2], "1 vs 7 threads");
}

#[test]
fn resnet_tiny_serves_offline_through_the_coordinator() {
    // Residual topologies lower into the graph IR since PR 4: a resnet
    // deployment serves offline and answers deterministically.
    let dep = fixed_dep("resnet-tiny");
    let server = Session::serve_with(
        &dep,
        BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(2),
        },
        ServeBackend::Sim,
    )
    .expect("resnet-tiny must be sim-servable");
    assert_eq!(server.backend_name, "sim");
    assert_eq!(server.input_dim(), 3 * 8 * 8);
    let x: Vec<f32> = (0..192).map(|j| ((j * 3) % 17) as f32 / 17.0).collect();
    let a = server.infer(x.clone()).expect("infer");
    let b = server.infer(x).expect("infer again");
    assert_eq!(a.len(), 10);
    assert_eq!(a, b, "same request, same logits");
    assert!(a.iter().all(|v| v.is_finite()));
    let m = server.snapshot_metrics();
    assert_eq!(m.requests, 2);
    assert_eq!(m.failures, 0);
}

#[test]
fn resnet18_deployment_is_servable_offline() {
    // Construction only (a debug-mode ResNet-18 forward is too slow for
    // the suite): standing the server up proves the artifact validates,
    // the full ImageNet residual topology lowers — 8 blocks, 3 projected
    // skips — and the coordinator wires up.
    let dep = fixed_dep("resnet18");
    let opts = ServeOptions {
        eval_batch: Some(1),
        ..ServeOptions::default()
    };
    let server = Session::serve_opts(&dep, BatchPolicy::default(), ServeBackend::Sim, opts)
        .expect("resnet18 must be sim-servable");
    assert_eq!(server.backend_name, "sim");
    assert_eq!(server.input_dim(), 3 * 224 * 224);
    assert_eq!(server.policy.len(), 21);
}

#[test]
fn unlowerable_topologies_are_typed_unsupported_errors() {
    // A custom network whose chain is broken cannot lower; serving it
    // must surface the typed capability error, not a runtime string.
    let net = nets::Network {
        name: "bad-chain".into(),
        layers: vec![
            nets::Layer::conv("c1", 3, 4, 3, 1, 1, 8),
            nets::Layer::conv("c2", 8, 4, 3, 1, 1, 8),
        ],
    };
    let err = lrmp::runtime::simnet::SimBackend::supports(&net).unwrap_err();
    assert!(err.contains("channels"), "{err}");
    // The same reason rides the typed ApiError (rendered by Display).
    let api = ApiError::UnsupportedNetwork {
        backend: "sim",
        net: net.name.clone(),
        reason: err,
    };
    let s = api.to_string();
    assert!(s.contains("bad-chain") && s.contains("channels"), "{s}");
}
