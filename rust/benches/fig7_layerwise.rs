//! Fig 7 reproduction: layer-wise breakdown of ResNet-18 latencies and tile
//! allocations for the baseline and the two LRMP modes. To isolate the
//! replication objective (the figure's point), both modes are solved on the
//! *same* LRMP-searched quantization policy. Paper observations:
//! the baseline is bottlenecked by conv1 (few tiles); latencyOptim cuts the
//! total by ~5× and the bottleneck by ~14× (13 extra copies);
//! throughputOptim cuts the total slightly less (~4.7×) but the bottleneck
//! by ~19× (18 extra copies) — "the bottleneck layer is solely responsible
//! for determining throughput, while all layers contribute to latency".

use lrmp::bench_harness::Table;
use lrmp::cost::CostModel;
use lrmp::lrmp::{Lrmp, SearchConfig};
use lrmp::nets;
use lrmp::quant::SqnrSurrogate;
use lrmp::replication::{self, LayerSummary, Objective};

fn main() {
    let net = nets::resnet::resnet18();
    let model = CostModel::paper();
    let base = model.baseline(&net);
    let n_tiles = net.tiles_at_uniform(model.chip.tile_size, 8, model.chip.device_bits);
    let episodes = std::env::var("LRMP_EPISODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(32);

    // One LRMP search provides the quantization policy ...
    let mut surrogate = SqnrSurrogate::for_benchmark(&net);
    let cfg = SearchConfig {
        objective: Objective::Latency,
        episodes,
        updates_per_episode: 4,
        lambda: 10.0,
        ..Default::default()
    };
    let res = Lrmp::new(&model, &net, cfg)
        .run(&mut surrogate)
        .expect("search");
    let policy = res.best_policy.clone();

    // ... and both replication objectives are solved exactly on it.
    let costs = model.layers(&net, &policy);
    let summaries = LayerSummary::from_costs(&costs);
    let lat_plan = replication::latency_optim(&summaries, n_tiles).expect("latencyOptim");
    let thr_plan = replication::throughput_optim(&summaries, n_tiles).expect("throughputOptim");
    let lat = model.network(&net, &policy, &lat_plan.replication);
    let thr = model.network(&net, &policy, &thr_plan.replication);

    println!(
        "=== Fig 7: ResNet18 layer-wise latency/tiles (policy from {episodes}-episode \
         search; both LP modes on the same policy) ===\n"
    );
    let mut t = Table::new(&[
        "layer",
        "base kcyc",
        "base tiles",
        "latOpt kcyc",
        "latOpt r",
        "thrOpt kcyc",
        "thrOpt r",
    ]);
    for (i, l) in net.layers.iter().enumerate() {
        t.row(&[
            l.name.clone(),
            format!("{:.0}", base.layer_cycles[i] / 1e3),
            base.layers[i].tiles.to_string(),
            format!("{:.0}", lat.layer_cycles[i] / 1e3),
            lat.replication[i].to_string(),
            format!("{:.0}", thr.layer_cycles[i] / 1e3),
            thr.replication[i].to_string(),
        ]);
    }
    t.print();

    let b = base.bottleneck_layer;
    let lat_total_x = base.total_cycles / lat.total_cycles;
    let thr_total_x = base.total_cycles / thr.total_cycles;
    let lat_bneck_x = base.layer_cycles[b] / lat.layer_cycles[b];
    let thr_bneck_x = base.layer_cycles[b] / thr.layer_cycles[b];
    let (lat_copies, thr_copies) = (lat.replication[b], thr.replication[b]);

    println!("\n=== paper vs measured ===\n");
    let mut s = Table::new(&["quantity", "paper", "ours"]);
    s.row(&[
        "baseline bottleneck".into(),
        "conv1 (first layer)".into(),
        net.layers[b].name.clone(),
    ]);
    s.row(&["latencyOptim total latency x".into(), "~5".into(), format!("{lat_total_x:.2}")]);
    s.row(&["latencyOptim bottleneck x".into(), "~14".into(), format!("{lat_bneck_x:.2}")]);
    s.row(&[
        "latencyOptim bottleneck copies".into(),
        "14 (13 extra)".into(),
        lat_copies.to_string(),
    ]);
    s.row(&["throughputOptim total latency x".into(), "~4.7".into(), format!("{thr_total_x:.2}")]);
    s.row(&["throughputOptim bottleneck x".into(), "~19".into(), format!("{thr_bneck_x:.2}")]);
    s.row(&[
        "throughputOptim bottleneck copies".into(),
        "19 (18 extra)".into(),
        thr_copies.to_string(),
    ]);
    s.print();

    // Shape assertions (guaranteed by optimality on a shared policy).
    assert_eq!(b, 0, "baseline bottleneck must be conv1");
    assert!(lat_total_x >= 4.0, "latencyOptim total x {lat_total_x}");
    assert!(
        lat_total_x >= thr_total_x - 1e-9,
        "latencyOptim must win on total latency ({lat_total_x} vs {thr_total_x})"
    );
    assert!(
        thr.bottleneck_cycles <= lat.bottleneck_cycles + 1e-9,
        "throughputOptim must win on the pipeline bottleneck (max over layers): \
         {} vs {}",
        thr.bottleneck_cycles,
        lat.bottleneck_cycles
    );
    // The pipeline-determining layer gets a deep cut in both modes (paper:
    // 14–19×); exact per-layer splits differ because throughputOptim
    // balances *all* near-bottleneck layers, not just conv1.
    assert!(lat_bneck_x >= 8.0, "latencyOptim bottleneck cut {lat_bneck_x}");
    assert!(thr_bneck_x >= 8.0, "throughputOptim bottleneck cut {thr_bneck_x}");
    assert!(
        thr_copies.max(lat_copies) >= 5,
        "the bottleneck must be heavily replicated ({lat_copies}/{thr_copies})"
    );
    println!("\nall Fig 7 shape assertions passed");
}
