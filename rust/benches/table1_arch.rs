//! Table I reproduction: the microarchitectural parameters of the scaled
//! ISSCC'22 system, plus the derived quantities the cost model relies on,
//! with validity assertions (ADC never clips, scaling relations hold).

use lrmp::arch::ChipConfig;
use lrmp::bench_harness::Table;

fn main() {
    let chip = ChipConfig::paper_scaled();
    assert!(chip.validate().is_empty(), "{:?}", chip.validate());

    println!("=== Table I: microarchitectural parameters (paper vs ours) ===\n");
    let mut t = Table::new(&["parameter", "paper", "ours"]);
    let rows: Vec<(&str, String, String)> = vec![
        ("eNVM", "1T-1R RRAM".into(), "1T-1R RRAM (modeled)".into()),
        ("tile size", "256x256".into(), format!("{0}x{0}", chip.tile_size)),
        ("no. of tiles", "5682".into(), chip.n_tiles.to_string()),
        ("no. of vector modules", "40".into(), chip.n_vector_modules.to_string()),
        ("device precision", "1 bit".into(), format!("{} bit", chip.device_bits)),
        ("row parallelism", "9".into(), chip.row_parallelism.to_string()),
        ("DAC precision", "1 bit".into(), format!("{} bit", chip.dac_bits)),
        ("column parallelism", "8".into(), chip.adcs_per_tile.to_string()),
        ("ADC precision", "4 bits".into(), format!("{} bits", chip.adc_bits)),
        ("avg power per tile", "70 uW".into(), format!("{:.0} uW", chip.tile_power_w * 1e6)),
        ("clock frequency", "192 MHz".into(), format!("{:.0} MHz", chip.clock_hz / 1e6)),
    ];
    for (p, a, b) in rows {
        t.row(&[p.to_string(), a, b]);
    }
    t.print();

    println!("\nderived quantities used by the cost model:");
    println!("  ADC batches per tile read      : {}", chip.adc_batches());
    println!("  row phases for a full tile     : {}", chip.row_phases(256));
    println!("  max analog partial sum         : {} (< 2^{} = {}; no clipping)",
        chip.max_partial_sum(), chip.adc_bits, 1u64 << chip.adc_bits);
    println!("  tiles per vector-module cluster: {}", chip.tiles_per_cluster());
    println!(
        "  base ISSCC'22 system scaling   : 288 tiles/2 VMs -> {} tiles/{} VMs",
        chip.n_tiles, chip.n_vector_modules
    );
    let base = ChipConfig::isscc22_base();
    assert_eq!(base.tiles_per_cluster(), 144);
    println!("\nall Table I assertions passed");
}
