//! Fig 4 reproduction: latency and throughput improvements of LRMP over the
//! 8-bit fixed-precision baselines, across all five benchmarks and both
//! optimization modes. Paper bands: latencyOptim → 2.8–9× latency and
//! 8–15× throughput; throughputOptim → 11.8–19× throughput and 2.5–8×
//! latency. Set LRMP_EPISODES to trade fidelity for wall-clock.

use lrmp::bench_harness::Table;
use lrmp::cost::CostModel;
use lrmp::lrmp::{Lrmp, SearchConfig};
use lrmp::nets;
use lrmp::quant::SqnrSurrogate;
use lrmp::replication::Objective;
use lrmp::util::stats;

fn episodes() -> usize {
    std::env::var("LRMP_EPISODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(32)
}

fn main() {
    let model = CostModel::paper();
    let eps = episodes();
    println!(
        "=== Fig 4: latency/throughput improvements at iso-area, iso-accuracy \
         ({eps} episodes/search) ===\n"
    );

    let mut t = Table::new(&[
        "benchmark",
        "mode",
        "latency x",
        "throughput x",
        "acc drop (ft)",
        "tiles used/budget",
        "secs",
    ]);
    let mut lat_latopt = Vec::new();
    let mut thr_thropt = Vec::new();

    for net in nets::paper_benchmarks() {
        for (mode, objective) in [
            ("latencyOptim", Objective::Latency),
            ("throughputOptim", Objective::Throughput),
        ] {
            let mut surrogate = SqnrSurrogate::for_benchmark(&net);
            // throughputOptim budgets the bottleneck layer, which replication
            // attacks directly — the paper reaches 11.8–19×, so its budget
            // tightens much further than the whole-network latency budget.
            let (b_start, b_end) = match objective {
                Objective::Latency => (0.35, 0.20),
                Objective::Throughput => (0.20, 0.08),
            };
            let cfg = SearchConfig {
                objective,
                episodes: eps,
                updates_per_episode: 4,
                lambda: 10.0,
                budget_start: b_start,
                budget_end: b_end,
                ..Default::default()
            };
            let search = Lrmp::new(&model, &net, cfg);
            let t0 = std::time::Instant::now();
            let res = search.run(&mut surrogate).expect("search");
            let secs = t0.elapsed().as_secs_f64();
            let lat = res.latency_improvement();
            let thr = res.throughput_improvement();
            if objective == Objective::Latency {
                lat_latopt.push(lat);
            } else {
                thr_thropt.push(thr);
            }
            t.row(&[
                net.name.clone(),
                mode.into(),
                format!("{lat:.2}"),
                format!("{thr:.2}"),
                format!("{:.3}", res.baseline_accuracy - res.finetuned_accuracy),
                format!("{}/{}", res.best_plan.tiles_used, search.baseline_tiles()),
                format!("{secs:.1}"),
            ]);
            assert!(
                res.best_plan.tiles_used <= search.baseline_tiles(),
                "{}: area constraint violated",
                net.name
            );
        }
    }
    t.print();

    println!("\npaper bands:  latencyOptim latency 2.8-9x;  throughputOptim throughput 11.8-19x");
    println!(
        "ours (range): latencyOptim latency {:.1}-{:.1}x (geomean {:.1}x); \
         throughputOptim throughput {:.1}-{:.1}x (geomean {:.1}x)",
        lat_latopt.iter().cloned().fold(f64::INFINITY, f64::min),
        lat_latopt.iter().cloned().fold(0.0, f64::max),
        stats::geomean(&lat_latopt),
        thr_thropt.iter().cloned().fold(f64::INFINITY, f64::min),
        thr_thropt.iter().cloned().fold(0.0, f64::max),
        stats::geomean(&thr_thropt),
    );

    // Shape assertions: every benchmark improves substantially in its
    // optimized dimension; magnitudes sit in (or above) the paper's bands.
    for (i, &l) in lat_latopt.iter().enumerate() {
        assert!(l >= 2.5, "benchmark {i}: latency improvement {l} < 2.5x");
    }
    for (i, &p) in thr_thropt.iter().enumerate() {
        assert!(p >= 8.0, "benchmark {i}: throughput improvement {p} < 8x");
    }
    println!("\nall Fig 4 shape assertions passed");
}
