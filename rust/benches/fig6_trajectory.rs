//! Fig 6 reproduction: the trajectory of the RL agent jointly optimizing
//! ResNet-18 for accuracy and latency — the budget starts lenient at 0.35×
//! baseline latency and tightens exponentially to 0.2×; over the episodes
//! the agent finds policies reaching ~5× latency improvement while holding
//! accuracy (paper: "upto 5× improvement in latency ... while also
//! improving the accuracy").

use lrmp::bench_harness::Table;
use lrmp::cost::CostModel;
use lrmp::lrmp::{Lrmp, SearchConfig};
use lrmp::nets;
use lrmp::quant::SqnrSurrogate;
use lrmp::replication::Objective;

fn main() {
    let net = nets::resnet::resnet18();
    let model = CostModel::paper();
    let episodes = std::env::var("LRMP_EPISODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(48);
    let cfg = SearchConfig {
        objective: Objective::Latency,
        episodes,
        updates_per_episode: 6,
        lambda: 10.0,
        budget_start: 0.35,
        budget_end: 0.20,
        ..Default::default()
    };
    let search = Lrmp::new(&model, &net, cfg);
    let mut surrogate = SqnrSurrogate::for_benchmark(&net);
    println!(
        "=== Fig 6: RL trajectory, ResNet18 latencyOptim, budget 0.35x -> 0.2x \
         ({episodes} episodes) ===\n"
    );
    let t0 = std::time::Instant::now();
    let res = search.run(&mut surrogate).expect("search");
    println!("search wall-clock: {:.1}s\n", t0.elapsed().as_secs_f64());

    let mut t = Table::new(&[
        "episode",
        "budget x",
        "latency x",
        "acc (reward est.)",
        "reward",
        "mean bits w/a",
    ]);
    for e in res
        .trajectory
        .iter()
        .step_by((episodes / 16).max(1))
        .chain(res.trajectory.last())
    {
        t.row(&[
            e.episode.to_string(),
            format!("{:.3}", e.budget_fraction),
            format!("{:.2}", e.latency_improvement),
            format!("{:.4}", e.accuracy),
            format!("{:+.3}", e.reward),
            format!("{:.1}/{:.1}", e.mean_w_bits, e.mean_a_bits),
        ]);
    }
    t.print();

    // --- Fig 6 shape assertions ---
    // (1) budget anchors: 0.35 → 0.20, exponentially monotone.
    assert!((res.trajectory[0].budget_fraction - 0.35).abs() < 1e-9);
    assert!((res.trajectory.last().unwrap().budget_fraction - 0.20).abs() < 1e-9);
    for w in res.trajectory.windows(2) {
        assert!(w[1].budget_fraction <= w[0].budget_fraction + 1e-12);
    }
    // (2) the agent reaches ~5× latency improvement (paper: "upto 5×").
    let best_lat = res
        .trajectory
        .iter()
        .map(|e| e.latency_improvement)
        .fold(0.0, f64::max);
    assert!(best_lat >= 4.5, "best latency improvement {best_lat} < 4.5x");
    // (3) late-phase rewards beat the early ones (the agent learns).
    let half = res.trajectory.len() / 2;
    let early: f64 = res.trajectory[..half]
        .iter()
        .map(|e| e.reward)
        .fold(f64::NEG_INFINITY, f64::max);
    let late: f64 = res.trajectory[half..]
        .iter()
        .map(|e| e.reward)
        .fold(f64::NEG_INFINITY, f64::max);
    println!(
        "\nbest latency improvement {best_lat:.2}x (paper: up to 5x); \
         best reward early {early:+.3} vs late {late:+.3}"
    );
    assert!(
        late >= early - 0.05,
        "agent failed to hold/improve reward: early {early} late {late}"
    );
    println!("all Fig 6 shape assertions passed");
}
