//! Fig 5 reproduction: energy improvements achieved by LRMP (a byproduct of
//! quantization shrinking the bit-slice/bit-stream products and of shorter
//! makespans cutting SRAM leakage). Paper: 5.5–10.6× (throughputOptim),
//! 5.5–9× (latencyOptim). The energy model components are RRAM tile energy,
//! vector-module SRAM accesses, and SRAM leakage (§VI-B).

use lrmp::bench_harness::Table;
use lrmp::cost::energy::EnergyReport;
use lrmp::cost::CostModel;
use lrmp::lrmp::{Lrmp, SearchConfig};
use lrmp::nets;
use lrmp::quant::SqnrSurrogate;
use lrmp::replication::Objective;

fn episodes() -> usize {
    std::env::var("LRMP_EPISODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(24)
}

fn main() {
    let model = CostModel::paper();
    let eps = episodes();
    println!("=== Fig 5: energy improvements ({eps} episodes/search) ===\n");
    let mut t = Table::new(&[
        "benchmark",
        "mode",
        "energy x",
        "tile mJ",
        "sram mJ",
        "leak mJ",
    ]);
    let mut improvements = Vec::new();
    for net in nets::paper_benchmarks() {
        let base = model.baseline(&net);
        let base_rep = EnergyReport::of(&base);
        for (mode, objective, b_end) in [
            ("latencyOptim", Objective::Latency, 0.20),
            ("throughputOptim", Objective::Throughput, 0.08),
        ] {
            let mut surrogate = SqnrSurrogate::for_benchmark(&net);
            let cfg = SearchConfig {
                objective,
                episodes: eps,
                updates_per_episode: 4,
                lambda: 10.0,
                budget_end: b_end,
                ..Default::default()
            };
            let res = Lrmp::new(&model, &net, cfg)
                .run(&mut surrogate)
                .expect("search");
            let rep = EnergyReport::of(&res.optimized);
            let imp = res.energy_improvement();
            improvements.push(imp);
            t.row(&[
                net.name.clone(),
                mode.into(),
                format!("{imp:.2}"),
                format!("{:.2}", rep.tile_j * 1e3),
                format!("{:.2}", rep.sram_dynamic_j * 1e3),
                format!("{:.2}", rep.sram_leak_j * 1e3),
            ]);
        }
        println!(
            "{} baseline energy: {:.2} mJ/inf (tile {:.2} / sram {:.2} / leak {:.2})",
            net.name,
            base_rep.total_j() * 1e3,
            base_rep.tile_j * 1e3,
            base_rep.sram_dynamic_j * 1e3,
            base_rep.sram_leak_j * 1e3
        );
    }
    println!();
    t.print();

    let min = improvements.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = improvements.iter().cloned().fold(0.0, f64::max);
    println!(
        "\npaper: 5.5-10.6x (throughputOptim), 5.5-9x (latencyOptim); ours: {min:.1}-{max:.1}x"
    );
    println!(
        "divergence note (EXPERIMENTS.md): our throughputOptim policies keep\n\
         non-bottleneck layers at high precision (Eqn 8 gives them no reason to\n\
         quantize), so their energy wins are smaller than the paper's; the\n\
         latencyOptim shape (multi-x, growing with quantization depth) matches."
    );
    // Shape: every configuration improves energy multiplicatively; the
    // latencyOptim runs land in the paper's decade (our SRAM/leakage
    // constants are 40nm-class estimates — DESIGN.md §6).
    for (i, &e) in improvements.iter().enumerate() {
        let is_latency_mode = i % 2 == 0;
        let floor = if is_latency_mode { 2.3 } else { 1.4 };
        assert!(e > floor, "config {i}: energy improvement {e} below {floor}");
        assert!(e < 20.0, "config {i}: energy improvement {e} implausible");
    }
    assert!(max > 5.0, "best energy improvement {max} should exceed 5x");
    println!("all Fig 5 shape assertions passed");
}
