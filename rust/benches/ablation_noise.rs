//! Extension ablation (beyond the paper — its §V-C defers non-idealities):
//! how analog device variation shifts the accuracy/performance trade-off of
//! the LRMP search on ResNet-18. Expectation: latency improvements are
//! noise-robust (they depend on geometry, not devices), while the
//! achievable accuracy degrades monotonically with σ_device and the agent
//! compensates by retaining more weight bits.

use lrmp::bench_harness::Table;
use lrmp::cost::CostModel;
use lrmp::lrmp::{AccuracyProvider, Lrmp, SearchConfig};
use lrmp::nets;
use lrmp::quant::nonideal::{NoisySurrogate, NonidealParams};
use lrmp::quant::SqnrSurrogate;

fn main() {
    let net = nets::resnet::resnet18();
    let model = CostModel::paper();
    let episodes = std::env::var("LRMP_EPISODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(20);
    println!(
        "=== Extension ablation: LRMP under analog device variation \
         (ResNet18, {episodes} episodes/point) ===\n"
    );

    let mut t = Table::new(&[
        "sigma_device",
        "latency x",
        "finetuned acc",
        "mean w bits",
        "baseline acc (noisy chip)",
    ]);
    let mut accs = Vec::new();
    let mut lats = Vec::new();
    for sigma in [0.0, 0.05, 0.10, 0.20] {
        let params = NonidealParams {
            sigma_device: sigma,
            ..NonidealParams::ideal()
        };
        let mut provider =
            NoisySurrogate::new(&net, SqnrSurrogate::for_benchmark(&net), params);
        let baseline_acc = provider.baseline();
        let cfg = SearchConfig {
            episodes,
            updates_per_episode: 4,
            lambda: 10.0,
            seed: 0x0a5e,
            ..Default::default()
        };
        let res = Lrmp::new(&model, &net, cfg)
            .run(&mut provider)
            .expect("search");
        let (mw, _) = res.best_policy.mean_bits();
        t.row(&[
            format!("{sigma:.2}"),
            format!("{:.2}", res.latency_improvement()),
            format!("{:.4}", res.finetuned_accuracy),
            format!("{mw:.1}"),
            format!("{baseline_acc:.4}"),
        ]);
        accs.push(res.finetuned_accuracy);
        lats.push(res.latency_improvement());
    }
    t.print();

    // Shape assertions.
    for w in accs.windows(2) {
        assert!(
            w[1] <= w[0] + 0.01,
            "accuracy should not improve with more device noise: {accs:?}"
        );
    }
    for &l in &lats {
        assert!(
            l >= 3.0,
            "latency improvements must be noise-robust (geometry-driven): {lats:?}"
        );
    }
    println!(
        "\nlatency improvements stay {:.1}-{:.1}x across the noise sweep while \
         accuracy degrades gracefully — LRMP's performance wins are device-robust.",
        lats.iter().cloned().fold(f64::INFINITY, f64::min),
        lats.iter().cloned().fold(0.0, f64::max)
    );
    println!("all noise-ablation assertions passed");
}
