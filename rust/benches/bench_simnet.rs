//! Sim-backend hot-path benchmark: the naive triple-loop quantized matmul
//! vs the PR 2 blocked `thread::scope` kernel vs the pooled register-tiled
//! kernel (`runtime::gemm` + `runtime::pool`), plus end-to-end `SimBackend`
//! steady-state eval latency per network — the **pass-optimized**
//! graph-schedule serving path against both a passes-off backend (the
//! within-run fused-vs-unfused comparison) and the straight-line reference
//! executor (`eval_reference`: fresh buffers per node, naive kernel, the
//! unoptimized graph by construction) on identical inputs. Networks
//! include `resnet-tiny`, so the residual path (skip slots, bit-exact
//! adds) is covered, and `conv-tiny`, whose Conv+Pool chain the pass
//! pipeline fuses. A counting global allocator measures allocations per
//! eval (zero after warmup is the contract on the FC path, and the bench
//! **fails** if an FC net allocates). A serving section stands up the
//! `lrmp::serve` multi-route front-end (incumbent + canary on one shared
//! pool) and records routed per-variant latency percentiles. A cost-model
//! section profiles the default chip (per-component area split, peak TOPS,
//! TOPS/W, TOPS/mm²) and the paper benchmark nets' achieved efficiency,
//! re-deriving every default-crossbar total through the schema-v1 closed
//! forms — cost model v2's identity knobs must not move a single bit of
//! the v5 aggregate cycles. A search section (new in schema v7) runs the
//! same small LRMP search serially and with a 4-way episode fan-out,
//! records episodes/sec and the cost-cache hit rate, and **fails** unless
//! the two Deployment artifacts match byte for byte. An overlap section
//! (new in schema v8) runs the same input pair back-to-back through an
//! overlap-off backend and as one `eval_pair` through the wavefront
//! executor (`SimOptions::overlap`) on conv-tiny, resnet-tiny and the
//! full VGG-16, records the pair p50 speedup against the `cost::overlap`
//! two-sample bottleneck prediction, and **fails** unless every logit of
//! every lane matches the serial executor bit for bit; a single fitted
//! fill-overhead scale and the calibrated model residuals are recorded
//! alongside (record-only). An int-kernels section (new in schema v9)
//! runs each net through an `--int-kernels`-on backend (eligible low-bit
//! layers dispatch to the packed-i8/i32 tier) and an int-off backend,
//! records per-net tier coverage, int-vs-f32 GFLOP/s and the eval p50
//! speedup, and **fails** unless the two tiers and the straight-line
//! reference agree on every logit bit. Emits a machine-readable
//! `BENCH_simnet.json` (schema v9, documented in
//! `rust/src/api/README.md`) that the CI `bench-smoke` job uploads and
//! gates on.
//!
//! Plain `fn main` bench (`harness = false`):
//!
//!   cargo bench --bench bench_simnet -- [--quick] [--out FILE]
//!       [--baseline FILE] [--summary FILE]
//!
//! `--quick` shrinks the sample budgets for the CI smoke job. The run
//! **fails (exit 1)** if any kernel's output diverges bitwise from the
//! naive reference, if the pass-optimized, passes-off and reference
//! executors disagree on any logit (residual adds and fused convs
//! included), if the overlapped executor's logits diverge bitwise from
//! the serial executor's (either `eval_pair` lane or the overlapped
//! single eval), if the integer kernel tier diverges bitwise from the
//! f32 path (or the tier gate runs vacuously, with no layer on each side
//! of the dispatch), if the cost model's default-crossbar totals diverge bitwise
//! from the schema-v1 closed forms, if a net with fused convs does not
//! shrink its arena, if the parallel search's Deployment artifact diverges
//! from the serial one (or its cost cache records no hits), if an
//! FC net's steady-state eval allocates, or — when `--baseline` points at
//! a *calibrated* committed `BENCH_simnet.json` — if the pooled aggregate
//! GFLOP/s regressed more than 20% against it. `--summary` additionally
//! writes the baseline comparison as markdown (CI appends it to the job
//! summary, with a loud warning while the committed baseline is still the
//! uncalibrated seed placeholder).

use lrmp::arch::ChipConfig;
use lrmp::bench_harness::{fmt_time, Bencher, Table};
use lrmp::cli::Args;
use lrmp::coordinator::InferenceBackend;
use lrmp::cost::breakdown::{ChipProfile, NetworkBreakdown};
use lrmp::cost::overlap::OverlapEstimate;
use lrmp::cost::{CostModel, NetworkCost, ACC_BITS};
use lrmp::nets::{self, LayerKind};
use lrmp::quant;
use lrmp::runtime::gemm::{self, ConvGeom, PackedMat};
use lrmp::runtime::passes::PassConfig;
use lrmp::runtime::pool::WorkerPool;
use lrmp::runtime::simnet::{SimBackend, SimOptions};
use lrmp::util::ceil_div;
use lrmp::util::json::Json;
use lrmp::util::prng::Rng;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Counts heap allocations so the bench can measure whether the
/// steady-state eval path stays allocation-free. Deallocation is not
/// counted: handing a buffer back to the caller is fine, creating a new
/// one is not.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc(layout) }
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.realloc(ptr, layout, new_size) }
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        unsafe { System.alloc_zeroed(layout) }
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// One naive-vs-scope-vs-pooled GEMM comparison row.
struct GemmRow {
    name: String,
    m: usize,
    k: usize,
    n: usize,
    naive: lrmp::bench_harness::BenchResult,
    blocked: lrmp::bench_harness::BenchResult,
    pooled: lrmp::bench_harness::BenchResult,
    blocked_exact: bool,
    pooled_exact: bool,
}

impl GemmRow {
    fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.k as f64 * self.n as f64
    }
    fn speedup(&self) -> f64 {
        self.naive.mean() / self.blocked.mean().max(1e-12)
    }
    fn pooled_speedup_vs_scope(&self) -> f64 {
        self.blocked.mean() / self.pooled.mean().max(1e-12)
    }
    fn gflops(&self, r: &lrmp::bench_harness::BenchResult) -> f64 {
        self.flops() / r.mean().max(1e-12) / 1e9
    }
}

/// One network's steady-state eval comparison: the pass-optimized
/// graph-schedule serving path vs the passes-off backend vs the
/// straight-line reference executor.
struct NetRow {
    net: String,
    b: usize,
    nl: usize,
    residual_adds: usize,
    fused_convs: usize,
    arena_bytes: usize,
    arena_bytes_unfused: usize,
    has_conv: bool,
    pooled: lrmp::bench_harness::BenchResult,
    unfused: lrmp::bench_harness::BenchResult,
    reference: lrmp::bench_harness::BenchResult,
    allocs_per_eval: f64,
    /// Pass-optimized logits == reference-executor logits, bit for bit.
    logits_exact: bool,
    /// Pass-optimized logits == passes-off logits, bit for bit.
    passes_exact: bool,
}

impl NetRow {
    fn eval_p50_speedup(&self) -> f64 {
        self.reference.p50() / self.pooled.p50().max(1e-12)
    }
    fn eval_p50_speedup_vs_unfused(&self) -> f64 {
        self.unfused.p50() / self.pooled.p50().max(1e-12)
    }
    /// A row with fused convs must shrink the arena; rows without fusions
    /// must leave it untouched.
    fn arena_ok(&self) -> bool {
        if self.fused_convs > 0 {
            self.arena_bytes < self.arena_bytes_unfused
        } else {
            self.arena_bytes == self.arena_bytes_unfused
        }
    }
}

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    // `cargo bench` forwards a bare `--bench` to harness=false targets.
    let args = Args::parse_with_switches(raw, &["quick", "bench"]);
    let quick = args.bool("quick");
    let out_path = args.str("out", "BENCH_simnet.json");

    let bench = if quick {
        Bencher {
            warmup: Duration::from_millis(10),
            min_time: Duration::from_millis(60),
            min_samples: 3,
            max_samples: 40,
        }
    } else {
        Bencher::default()
    };

    let threads = gemm::worker_threads();
    println!(
        "=== sim backend hot path: naive vs scope-blocked vs pooled-tiled matmul ===\n\
         (threads {threads}, {} profile)\n",
        if quick { "quick" } else { "full" }
    );

    // --- GEMM kernel comparison over the paper MLP's layer shapes ------
    let pool = WorkerPool::new(threads);
    let batch = 16usize;
    let dims = [784usize, 1024, 4096, 4096, 1024, 10];
    let mut rng = Rng::new(0xBE7C);
    let mut rows: Vec<GemmRow> = Vec::new();
    for (l, w) in dims.windows(2).enumerate() {
        let (k, n) = (w[0], w[1]);
        // Post-ReLU-like inputs: ~1/3 exact zeros, the rest positive.
        let x: Vec<f32> = (0..batch * k)
            .map(|i| {
                if i % 3 == 0 {
                    0.0
                } else {
                    (rng.f64() * 0.9 + 0.05) as f32
                }
            })
            .collect();
        let wm: Vec<f32> = (0..k * n).map(|_| (rng.normal() * 0.1) as f32).collect();
        let packed = PackedMat::pack(&wm, k, n);

        let mut y_naive = vec![0f32; batch * n];
        let mut y_blocked = vec![0f32; batch * n];
        let mut y_pooled = vec![0f32; batch * n];
        gemm::matmul_naive(&x, &wm, batch, k, n, &mut y_naive);
        gemm::matmul_blocked(&x, &packed, batch, &mut y_blocked);
        gemm::matmul_pooled(&x, &packed, batch, &pool, &mut y_pooled);
        let blocked_exact = bits_of(&y_naive) == bits_of(&y_blocked);
        let pooled_exact = bits_of(&y_naive) == bits_of(&y_pooled);

        let name = format!("fc{} {}x{}x{}", l + 1, batch, k, n);
        let naive = bench.run(&format!("{name} naive"), || {
            gemm::matmul_naive(&x, &wm, batch, k, n, &mut y_naive);
        });
        let blocked = bench.run(&format!("{name} scope"), || {
            gemm::matmul_blocked(&x, &packed, batch, &mut y_blocked);
        });
        let pooled = bench.run(&format!("{name} pooled"), || {
            gemm::matmul_pooled(&x, &packed, batch, &pool, &mut y_pooled);
        });
        rows.push(GemmRow {
            name,
            m: batch,
            k,
            n,
            naive,
            blocked,
            pooled,
            blocked_exact,
            pooled_exact,
        });
    }

    let naive_total: f64 = rows.iter().map(|r| r.naive.mean()).sum();
    let blocked_total: f64 = rows.iter().map(|r| r.blocked.mean()).sum();
    let pooled_total: f64 = rows.iter().map(|r| r.pooled.mean()).sum();
    let mlp_speedup = naive_total / blocked_total.max(1e-12);
    let mlp_pooled_speedup = naive_total / pooled_total.max(1e-12);
    let pooled_gflops_mean =
        rows.iter().map(|r| r.gflops(&r.pooled)).sum::<f64>() / rows.len().max(1) as f64;

    let mut t = Table::new(&[
        "shape",
        "naive",
        "scope",
        "pooled",
        "pool vs scope",
        "GFLOP/s pooled",
        "bit-exact",
    ]);
    for r in &rows {
        t.row(&[
            r.name.clone(),
            fmt_time(r.naive.mean()),
            fmt_time(r.blocked.mean()),
            fmt_time(r.pooled.mean()),
            format!("x{:.2}", r.pooled_speedup_vs_scope()),
            format!("{:.2}", r.gflops(&r.pooled)),
            (r.blocked_exact && r.pooled_exact).to_string(),
        ]);
    }
    t.print();
    println!(
        "\nMLP eval path (sum of layer GEMMs, batch {batch}): naive {} vs scope {} vs \
         pooled {} -> pooled x{:.2} over naive, x{:.2} over scope\n",
        fmt_time(naive_total),
        fmt_time(blocked_total),
        fmt_time(pooled_total),
        mlp_pooled_speedup,
        blocked_total / pooled_total.max(1e-12),
    );

    // --- conv lowering correctness (both kernels vs direct conv) -------
    let conv_exact = conv_lowering_bit_exact(None);
    let pooled_conv_exact = conv_lowering_bit_exact(Some(&pool));
    println!("conv lowering scope kernel == direct reference:  {conv_exact}");
    println!("conv lowering pooled kernel == direct reference: {pooled_conv_exact}\n");

    // --- end-to-end SimBackend steady-state eval: graph vs reference ---
    // `resnet-tiny` covers the residual path: its logits ride through two
    // Add nodes, and the bitwise gate below compares them against the
    // straight-line reference executor.
    let net_bench = if quick {
        Bencher {
            warmup: Duration::from_millis(10),
            min_time: Duration::from_millis(80),
            min_samples: 3,
            max_samples: 20,
        }
    } else {
        Bencher::quick()
    };
    let mut net_rows: Vec<NetRow> = Vec::new();
    for name in ["mlp-tiny", "mlp", "conv-tiny", "resnet-tiny"] {
        let net = nets::by_name(name).expect("bench nets are registered");
        let b = 16usize;
        let mut backend = SimBackend::from_network(&net, b, 7).expect("sim-supported net");
        let mut plain = SimBackend::from_network_cfg(
            &net,
            b,
            7,
            SimOptions {
                passes: PassConfig::none(),
                ..SimOptions::default()
            },
        )
        .expect("sim-supported net");
        let dim = backend.input_dim();
        let nl = backend.num_layers();
        let residual_adds = backend.graph().residual_adds();
        let fused_convs = backend.graph().fused_convs();
        let arena_bytes = backend.schedule_summary().arena_bytes;
        let arena_bytes_unfused = plain.schedule_summary().arena_bytes;
        let has_conv = net
            .layers
            .iter()
            .any(|l| matches!(l.kind, LayerKind::Conv2d { .. }));
        let x: Vec<f32> = (0..b * dim).map(|i| ((i * 31) % 97) as f32 / 97.0).collect();
        let (wb, ab) = (vec![5.0f32; nl], vec![6.0f32; nl]);

        // The three executors must agree on every logit bit before they
        // race: pass-optimized vs reference (every pass adversarially
        // checked against the unoptimized straight-line graph) and
        // pass-optimized vs passes-off (same hot path, no rewrites).
        let yp = backend.eval(x.clone(), wb.clone(), ab.clone()).unwrap();
        let yu = plain.eval(x.clone(), wb.clone(), ab.clone()).unwrap();
        let yr = backend.eval_reference(&x, &wb, &ab);
        let logits_exact = bits_of(&yp) == bits_of(&yr);
        let passes_exact = bits_of(&yp) == bits_of(&yu);

        let pooled = net_bench.run(&format!("eval {} graph b={b}", net.name), || {
            let y = backend.eval(x.clone(), wb.clone(), ab.clone()).unwrap();
            std::hint::black_box(y);
        });
        let unfused = net_bench.run(&format!("eval {} passes-off b={b}", net.name), || {
            let y = plain.eval(x.clone(), wb.clone(), ab.clone()).unwrap();
            std::hint::black_box(y);
        });
        let reference = net_bench.run(&format!("eval {} reference b={b}", net.name), || {
            let y = backend.eval_reference(&x, &wb, &ab);
            std::hint::black_box(y);
        });
        let allocs = allocs_per_eval(&mut backend, &x, &wb, &ab);
        println!(
            "  -> {} {:.1} inferences/s graph path (p50 {}, p95 {}), x{:.2} over \
             passes-off, x{:.2} over the straight-line reference, {:.1} allocs/eval, \
             {} residual add(s), {} fused conv(s), arena {} -> {} B, logits bit-exact \
             {} (passes {})",
            net.name,
            b as f64 / pooled.mean().max(1e-12),
            fmt_time(pooled.p50()),
            fmt_time(pooled.p95()),
            unfused.p50() / pooled.p50().max(1e-12),
            reference.p50() / pooled.p50().max(1e-12),
            allocs,
            residual_adds,
            fused_convs,
            arena_bytes_unfused,
            arena_bytes,
            logits_exact,
            passes_exact
        );
        net_rows.push(NetRow {
            net: net.name.clone(),
            b,
            nl,
            residual_adds,
            fused_convs,
            arena_bytes,
            arena_bytes_unfused,
            has_conv,
            pooled,
            unfused,
            reference,
            allocs_per_eval: allocs,
            logits_exact,
            passes_exact,
        });
    }

    // --- multi-route serving front-end: routed latency smoke -----------
    // One route, an 8-bit incumbent with a 5/6-bit canary on 25% of its
    // traffic, both sim backends over one shared pool — the same path the
    // CI serving-smoke step drives through the binary. The gate below
    // requires both variants to have served their routed share with sane
    // latency percentiles.
    let serving_reqs: usize = if quick { 64 } else { 256 };
    let (serving_json, serving_ok) = {
        use lrmp::api::ServeOptions;
        use lrmp::replication::Objective;
        use lrmp::serve::{CanarySpec, DeploymentSource, MultiServer, RouteSpec, RoutesConfig};
        let uniform = |w_bits: u32, a_bits: u32| DeploymentSource::Uniform {
            net: "mlp-tiny".into(),
            objective: Objective::Latency,
            w_bits,
            a_bits,
        };
        let cfg = RoutesConfig {
            routes: vec![RouteSpec {
                name: "bench".into(),
                weight: 1.0,
                source: uniform(8, 8),
                max_batch: Some(8),
                deadline_ms: Some(1),
                eval_batch: Some(16),
                canary: Some(CanarySpec {
                    source: uniform(5, 6),
                    fraction: 0.25,
                }),
            }],
        };
        let ms = MultiServer::start(
            &cfg,
            ServeOptions {
                threads: Some(threads),
                ..ServeOptions::default()
            },
        )
        .expect("bench route config stands up");
        let dim = ms.input_dim("bench").expect("route is registered");
        let t0 = std::time::Instant::now();
        for i in 0..serving_reqs {
            let x: Vec<f32> = (0..dim)
                .map(|j| ((i * 13 + j * 7) % 31) as f32 / 31.0)
                .collect();
            let y = ms.infer("bench", x).expect("routed infer");
            std::hint::black_box(y);
        }
        let wall = t0.elapsed().as_secs_f64();
        let report = ms.route_report("bench").expect("route is registered");
        let ok = report.variants.iter().all(|v| {
            v.routed > 0 && v.metrics.requests == v.routed && v.metrics.latency_p(99.0) > 0.0
        });
        for v in &report.variants {
            println!(
                "  -> serve route bench/{}: {} routed, p50 {}, p99 {}",
                v.label,
                v.routed,
                fmt_time(v.metrics.latency_p(50.0)),
                fmt_time(v.metrics.latency_p(99.0)),
            );
        }
        let variants = Json::Arr(
            report
                .variants
                .iter()
                .map(|v| {
                    Json::obj(vec![
                        ("label", Json::Str(v.label.clone())),
                        ("key", Json::Str(v.key.to_string())),
                        ("routed", Json::Num(v.routed as f64)),
                        ("metrics", v.metrics.to_json()),
                    ])
                })
                .collect(),
        );
        let j = Json::obj(vec![
            ("route", Json::Str(report.name.clone())),
            ("requests", Json::Num(serving_reqs as f64)),
            ("wall_s", Json::Num(wall)),
            ("rps", Json::Num(serving_reqs as f64 / wall.max(1e-12))),
            ("variants", variants),
        ]);
        (j, ok)
    };

    // --- cost model v2 breakdown (new in schema v6) --------------------
    // The default-crossbar chip's component profile plus per-net achieved
    // TOPS/W and TOPS/mm² on the paper benchmark nets, straight from the
    // analytical cost model (no timing noise — a pure artifact block).
    // Every net's totals are re-derived through the schema-v1 closed
    // forms and compared bit for bit: cost model v2's identity knobs
    // (crossbar, ADC share 1, 1-bit streaming) must not move the v5
    // aggregate cycles or energy at all.
    let (breakdown_json, cost_v1_bitwise_ok) = {
        let chip = ChipConfig::paper_scaled();
        let model = CostModel::new(chip.clone());
        let profile = ChipProfile::of(&chip);
        println!(
            "cost-model profile: {} array, chip {:.1} mm2, peak {:.1} TOPS, \
             {:.1} TOPS/W, {:.3} TOPS/mm2",
            chip.array_type.as_str(),
            profile.chip_area_mm2,
            profile.tops_peak,
            profile.topsw_peak,
            profile.topsmm2_peak,
        );
        let mut nets_bd: Vec<Json> = Vec::new();
        let mut all_bitwise = true;
        for name in ["mlp", "resnet18", "resnet34", "resnet50", "resnet101"] {
            let net = nets::by_name(name).expect("paper nets are registered");
            let cost = model.baseline(&net);
            let bd = NetworkBreakdown::of(&chip, &cost);
            let bitwise = v1_totals_bitwise(&model, &net, &cost);
            all_bitwise &= bitwise;
            // 2 ops per (8-bit) MAC of the lowered GEMMs.
            let ops: f64 = net
                .layers
                .iter()
                .map(|l| {
                    2.0 * l.lowered_rows() as f64
                        * l.lowered_cols() as f64
                        * l.num_vectors() as f64
                })
                .sum();
            let tops_w = ops / cost.energy_j.max(1e-30) / 1e12;
            let tops_mm2 = ops * cost.throughput() / profile.chip_area_mm2.max(1e-30) / 1e12;
            println!(
                "  -> {name}: {} tiles, latency {:.2} ms, {:.1} uJ/inf, \
                 {:.3} TOPS/W, {:.4} TOPS/mm2, v1-bitwise {bitwise}",
                cost.tiles_used,
                cost.latency_s() * 1e3,
                cost.energy_j * 1e6,
                tops_w,
                tops_mm2,
            );
            nets_bd.push(Json::obj(vec![
                ("net", Json::Str(name.into())),
                ("tiles", Json::Num(cost.tiles_used as f64)),
                ("latency_s", Json::Num(cost.latency_s())),
                ("energy_j", Json::Num(cost.energy_j)),
                ("tops_w", Json::Num(tops_w)),
                ("tops_mm2", Json::Num(tops_mm2)),
                ("tile_energy_split_j", bd.energy_j.to_json()),
                ("v1_bitwise", Json::Bool(bitwise)),
            ]));
        }
        println!();
        let j = Json::obj(vec![
            ("chip", profile.to_json()),
            ("nets", Json::Arr(nets_bd)),
            ("v1_totals_bitwise", Json::Bool(all_bitwise)),
        ]);
        (j, all_bitwise)
    };

    // --- parallel search fan-out (new in schema v7) --------------------
    // The same small LRMP search runs twice — serial and with a 4-way
    // episode fan-out across all NVM array candidates — and the two
    // Deployment artifacts must match byte for byte (the CI search-smoke
    // step drives the same contract through the binary). Episodes/sec and
    // the cost-cache hit rate are recorded; the speedup itself is
    // machine-dependent (CI runners are 2-core VMs) and not gated.
    let search_episodes: usize = if quick { 6 } else { 16 };
    let search_threads = 4usize;
    let (search_json, search_md, search_artifact_identical, search_hit_rate) = {
        use lrmp::api::Session;
        use lrmp::arch::ArrayType;
        let run = |threads: usize| {
            let t0 = std::time::Instant::now();
            let (dep, res) = Session::new("mlp")
                .expect("bench net is registered")
                .episodes(search_episodes)
                .updates_per_episode(2)
                .seed(0xA11CE)
                .arrays(ArrayType::all().to_vec())
                .search_threads(threads)
                .search_detailed()
                .expect("bench search runs");
            (t0.elapsed().as_secs_f64(), dep, res)
        };
        let (wall_1, dep_1, _res_1) = run(1);
        let (wall_n, dep_n, res_n) = run(search_threads);
        let identical = dep_1.to_json().pretty() == dep_n.to_json().pretty();
        let eps_1 = search_episodes as f64 / wall_1.max(1e-12);
        let eps_n = search_episodes as f64 / wall_n.max(1e-12);
        let speedup = eps_n / eps_1.max(1e-12);
        let hit_rate = res_n.stats.cache_hit_rate();
        println!(
            "search fan-out ({search_episodes} episodes, all arrays): serial {eps_1:.1} ep/s, \
             {search_threads} threads {eps_n:.1} ep/s (x{speedup:.2}), cost-cache hit rate \
             {:.1}%, artifact bitwise identical {identical}\n",
            hit_rate * 100.0,
        );
        let j = Json::obj(vec![
            ("net", Json::Str(dep_1.net.clone())),
            ("episodes", Json::Num(search_episodes as f64)),
            ("threads", Json::Num(search_threads as f64)),
            ("episodes_per_s_serial", Json::Num(eps_1)),
            ("episodes_per_s_parallel", Json::Num(eps_n)),
            ("speedup", Json::Num(speedup)),
            ("cost_cache_hit_rate", Json::Num(hit_rate)),
            ("artifact_bitwise_identical", Json::Bool(identical)),
        ]);
        let md = format!(
            "\n## search fan-out ({search_episodes} episodes, serial vs {search_threads} \
             threads)\n\n\
             | episodes/s serial | episodes/s parallel | speedup | cost-cache hit rate | \
             artifact bitwise identical |\n|---|---|---|---|---|\n\
             | {eps_1:.1} | {eps_n:.1} | x{speedup:.2} | {:.1}% | {identical} |\n",
            hit_rate * 100.0,
        );
        (j, md, identical, hit_rate)
    };

    // --- overlapped graph execution (new in schema v8) -----------------
    // The same two inputs run (a) back-to-back through an overlap-off
    // backend and (b) as one `eval_pair` through the wavefront executor
    // (`SimOptions::overlap`: branch-parallel waves + inter-eval
    // pipelining). Every logit of both lanes — and of a plain `eval`
    // routed through the overlapped executor — must match the serial
    // executor bit for bit; overlap changes scheduling, never values.
    // The pair p50s give the measured pipelining speedup (machine-
    // dependent: the win needs more worker threads than the per-eval
    // conv fan-out can fill, so 2-core CI runners sit near 1.0×), which
    // is recorded against the `cost::overlap` two-sample bottleneck
    // prediction 2S / (F + 2B). The backends are built sequentially —
    // VGG-16's packed weights are ~0.5 GB, so the serial backend is
    // dropped before the overlapped one is stood up.
    struct OverlapRow {
        net: String,
        b: usize,
        serial_pair: lrmp::bench_harness::BenchResult,
        pipelined_pair: lrmp::bench_harness::BenchResult,
        bit_exact: bool,
        predicted_speedup: f64,
        // `cost::overlap` terms of this net's estimate, kept so the
        // fill-overhead calibration below can re-predict with a fitted
        // fill scale.
        serial_cycles: f64,
        steady_cycles: f64,
        fill_cycles: f64,
    }
    impl OverlapRow {
        fn measured_speedup(&self) -> f64 {
            self.serial_pair.p50() / self.pipelined_pair.p50().max(1e-12)
        }
        fn model_rel_error(&self) -> f64 {
            (self.predicted_speedup - self.measured_speedup()).abs()
                / self.measured_speedup().max(1e-12)
        }
    }
    let ov_bench = Bencher {
        warmup: Duration::from_millis(10),
        min_time: Duration::from_millis(if quick { 10 } else { 200 }),
        min_samples: 2,
        max_samples: if quick { 3 } else { 8 },
    };
    let mut ov_rows: Vec<OverlapRow> = Vec::new();
    for (name, b) in [("conv-tiny", 8usize), ("resnet-tiny", 8), ("vgg16", 2)] {
        let net = nets::by_name(name).expect("bench nets are registered");
        let mut serial =
            SimBackend::from_network_cfg(&net, b, 7, SimOptions::default()).expect("sim net");
        let dim = serial.input_dim();
        let nl = serial.num_layers();
        let x0: Vec<f32> = (0..b * dim)
            .map(|i| ((i * 17) % 59) as f32 / 59.0 - 0.3)
            .collect();
        let x1: Vec<f32> = (0..b * dim)
            .map(|i| ((i * 23) % 71) as f32 / 71.0 - 0.1)
            .collect();
        let (wb, ab) = (vec![5.0f32; nl], vec![6.0f32; nl]);
        let y0 = serial.eval(x0.clone(), wb.clone(), ab.clone()).unwrap();
        let y1 = serial.eval(x1.clone(), wb.clone(), ab.clone()).unwrap();
        let serial_pair = ov_bench.run(&format!("eval {} serial pair b={b}", net.name), || {
            let a = serial.eval(x0.clone(), wb.clone(), ab.clone()).unwrap();
            let c = serial.eval(x1.clone(), wb.clone(), ab.clone()).unwrap();
            std::hint::black_box((a, c));
        });
        drop(serial);
        let mut overlapped = SimBackend::from_network_cfg(
            &net,
            b,
            7,
            SimOptions {
                overlap: true,
                ..SimOptions::default()
            },
        )
        .expect("sim net");
        let ys = overlapped.eval(x0.clone(), wb.clone(), ab.clone()).unwrap();
        let (p0, p1) = overlapped.eval_pair(&x0, &x1, &wb, &ab).unwrap();
        let bit_exact = bits_of(&p0) == bits_of(&y0)
            && bits_of(&p1) == bits_of(&y1)
            && bits_of(&ys) == bits_of(&y0);
        let pipelined_pair =
            ov_bench.run(&format!("eval {} pipelined pair b={b}", net.name), || {
                let (a, c) = overlapped.eval_pair(&x0, &x1, &wb, &ab).unwrap();
                std::hint::black_box((a, c));
            });
        let chip_cost = CostModel::new(ChipConfig::paper_scaled()).baseline(&net);
        let est = OverlapEstimate::from_cost(&chip_cost);
        let predicted_speedup =
            2.0 * est.serial_cycles / est.pipelined_latency_cycles(2).max(1e-12);
        let row = OverlapRow {
            net: net.name.clone(),
            b,
            serial_pair,
            pipelined_pair,
            bit_exact,
            predicted_speedup,
            serial_cycles: est.serial_cycles,
            steady_cycles: est.steady_cycles,
            fill_cycles: est.fill_cycles,
        };
        println!(
            "  -> overlap {}: serial pair p50 {}, pipelined pair p50 {}, x{:.2} measured \
             (bottleneck model x{:.2}, rel err {:.0}%), bit-exact {}",
            row.net,
            fmt_time(row.serial_pair.p50()),
            fmt_time(row.pipelined_pair.p50()),
            row.measured_speedup(),
            row.predicted_speedup,
            row.model_rel_error() * 100.0,
            row.bit_exact,
        );
        ov_rows.push(row);
    }
    println!();
    let overlap_bit_exact = ov_rows.iter().all(|r| r.bit_exact);
    // ROADMAP calibration item: the uncalibrated bottleneck model charges
    // the pipeline fill at face value (pair latency F + 2B). Fit a single
    // fill-overhead scale s from the measured pair p50s — per net,
    // 2S/(s·F + 2B) = measured solves to s = (2S/measured − 2B)/F — and
    // aggregate with the median, clamped at 0. Record-only, no gate: the
    // measured speedups are machine-dependent (see above), the calibrated
    // residual just shows how much of the model error one fill knob
    // absorbs on this machine.
    let fill_scale_calibrated = {
        let mut scales: Vec<f64> = ov_rows
            .iter()
            .filter_map(|r| {
                let measured = r.measured_speedup();
                (measured > 0.0 && r.fill_cycles > 0.0).then(|| {
                    ((2.0 * r.serial_cycles / measured - 2.0 * r.steady_cycles)
                        / r.fill_cycles)
                        .max(0.0)
                })
            })
            .collect();
        scales.sort_by(|a, b| a.total_cmp(b));
        if scales.is_empty() {
            1.0
        } else {
            scales[scales.len() / 2]
        }
    };
    let calibrated_rel_error = |r: &OverlapRow| {
        let measured = r.measured_speedup();
        let pred = 2.0 * r.serial_cycles
            / (fill_scale_calibrated * r.fill_cycles + 2.0 * r.steady_cycles).max(1e-12);
        (pred - measured).abs() / measured.max(1e-12)
    };
    println!(
        "  overlap fill calibration: fitted fill scale {fill_scale_calibrated:.3}, \
         calibrated rel err {}\n",
        ov_rows
            .iter()
            .map(|r| format!("{} {:.0}%", r.net, calibrated_rel_error(r) * 100.0))
            .collect::<Vec<_>>()
            .join(", "),
    );
    let overlap_json = Json::obj(vec![
        (
            "nets",
            Json::Arr(
                ov_rows
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("net", Json::Str(r.net.clone())),
                            ("eval_batch", Json::Num(r.b as f64)),
                            ("serial_pair_p50_s", Json::Num(r.serial_pair.p50())),
                            ("pipelined_pair_p50_s", Json::Num(r.pipelined_pair.p50())),
                            ("measured_pair_speedup", Json::Num(r.measured_speedup())),
                            ("predicted_pair_speedup", Json::Num(r.predicted_speedup)),
                            ("model_rel_error", Json::Num(r.model_rel_error())),
                            (
                                "model_rel_error_calibrated",
                                Json::Num(calibrated_rel_error(r)),
                            ),
                            ("bit_exact", Json::Bool(r.bit_exact)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("fill_scale_calibrated", Json::Num(fill_scale_calibrated)),
        ("overlap_bit_exact", Json::Bool(overlap_bit_exact)),
    ]);
    let ov_md = {
        let mut md = String::from(
            "\n## overlapped execution (pair of evals, serial vs `eval_pair`)\n\n\
             | net | batch | serial pair p50 | pipelined pair p50 | measured | model | \
             bit-exact |\n|---|---|---|---|---|---|---|\n",
        );
        for r in &ov_rows {
            md += &format!(
                "| {} | {} | {} | {} | x{:.2} | x{:.2} | {} |\n",
                r.net,
                r.b,
                fmt_time(r.serial_pair.p50()),
                fmt_time(r.pipelined_pair.p50()),
                r.measured_speedup(),
                r.predicted_speedup,
                r.bit_exact,
            );
        }
        md
    };

    // --- precision-tiered integer kernels (new in schema v9) -----------
    // Each net runs the same input through an `--int-kernels`-on backend
    // (layers whose searched bits satisfy k·(2^w−1)(2^a−1) < 2^24 dispatch
    // to the packed-i8/i32 tier) and an int-off backend (every layer
    // pinned to f32). The two tiers — and the straight-line reference —
    // must agree on every logit bit: the predicate makes the integer path
    // exact, not approximately equal. The p50s give the realized eval
    // speedup. mlp-tiny runs at 8/8 where its 512-deep layers are
    // ineligible, so the mixed dispatch (int layers feeding f32 fallback
    // layers and back) is exercised, not just the all-int happy path.
    struct IntRow {
        net: String,
        b: usize,
        w_bits: u32,
        a_bits: u32,
        eligible: usize,
        total: usize,
        /// f32-equivalent FLOPs of one batched eval (2·R·C·V per layer).
        flops: f64,
        int_on: lrmp::bench_harness::BenchResult,
        int_off: lrmp::bench_harness::BenchResult,
        bit_exact: bool,
    }
    impl IntRow {
        fn coverage(&self) -> f64 {
            self.eligible as f64 / self.total.max(1) as f64
        }
        fn speedup(&self) -> f64 {
            self.int_off.p50() / self.int_on.p50().max(1e-12)
        }
        fn gflops(&self, r: &lrmp::bench_harness::BenchResult) -> f64 {
            self.flops / r.p50().max(1e-12) / 1e9
        }
    }
    let mut int_rows: Vec<IntRow> = Vec::new();
    for (name, b, w_bits, a_bits) in [
        ("mlp-tiny", 16usize, 8u32, 8u32),
        ("mlp", 16, 5, 6),
        ("conv-tiny", 16, 6, 6),
        ("resnet-tiny", 8, 6, 6),
    ] {
        let net = nets::by_name(name).expect("bench nets are registered");
        let mut on =
            SimBackend::from_network_cfg(&net, b, 7, SimOptions::default()).expect("sim net");
        let mut off = SimBackend::from_network_cfg(
            &net,
            b,
            7,
            SimOptions {
                int_kernels: false,
                ..SimOptions::default()
            },
        )
        .expect("sim net");
        let dim = on.input_dim();
        let nl = on.num_layers();
        let x: Vec<f32> = (0..b * dim)
            .map(|i| ((i * 29) % 83) as f32 / 83.0 - 0.2)
            .collect();
        let (wb, ab) = (vec![w_bits as f32; nl], vec![a_bits as f32; nl]);
        let y_on = on.eval(x.clone(), wb.clone(), ab.clone()).unwrap();
        let y_off = off.eval(x.clone(), wb.clone(), ab.clone()).unwrap();
        let y_ref = off.eval_reference(&x, &wb, &ab);
        let bit_exact = bits_of(&y_on) == bits_of(&y_off) && bits_of(&y_on) == bits_of(&y_ref);
        let int_on = net_bench.run(&format!("eval {} int-on b={b}", net.name), || {
            let y = on.eval(x.clone(), wb.clone(), ab.clone()).unwrap();
            std::hint::black_box(y);
        });
        let int_off = net_bench.run(&format!("eval {} int-off b={b}", net.name), || {
            let y = off.eval(x.clone(), wb.clone(), ab.clone()).unwrap();
            std::hint::black_box(y);
        });
        let eligible = net
            .layers
            .iter()
            .filter(|l| quant::int_exact_bits(w_bits, a_bits, l.lowered_rows() as usize))
            .count();
        let flops: f64 = b as f64
            * net
                .layers
                .iter()
                .map(|l| {
                    2.0 * l.lowered_rows() as f64
                        * l.lowered_cols() as f64
                        * l.num_vectors() as f64
                })
                .sum::<f64>();
        let row = IntRow {
            net: net.name.clone(),
            b,
            w_bits,
            a_bits,
            eligible,
            total: net.layers.len(),
            flops,
            int_on,
            int_off,
            bit_exact,
        };
        println!(
            "  -> int tier {} ({w_bits}/{a_bits} bits): {}/{} layers eligible, int p50 {} \
             ({:.2} GFLOP/s) vs f32 p50 {} ({:.2} GFLOP/s) -> x{:.2}, bit-exact {}",
            row.net,
            row.eligible,
            row.total,
            fmt_time(row.int_on.p50()),
            row.gflops(&row.int_on),
            fmt_time(row.int_off.p50()),
            row.gflops(&row.int_off),
            row.speedup(),
            row.bit_exact,
        );
        int_rows.push(row);
    }
    println!();
    let int_bit_exact = int_rows.iter().all(|r| r.bit_exact);
    // The gate is only meaningful if both sides of the dispatch ran: at
    // least one layer on the integer tier and at least one f32 fallback.
    let int_nonvacuous = int_rows.iter().any(|r| r.eligible > 0)
        && int_rows.iter().any(|r| r.eligible < r.total);
    let int_json = Json::obj(vec![
        (
            "nets",
            Json::Arr(
                int_rows
                    .iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("net", Json::Str(r.net.clone())),
                            ("eval_batch", Json::Num(r.b as f64)),
                            ("w_bits", Json::Num(r.w_bits as f64)),
                            ("a_bits", Json::Num(r.a_bits as f64)),
                            ("eligible_layers", Json::Num(r.eligible as f64)),
                            ("total_layers", Json::Num(r.total as f64)),
                            ("coverage", Json::Num(r.coverage())),
                            ("int_p50_s", Json::Num(r.int_on.p50())),
                            ("f32_p50_s", Json::Num(r.int_off.p50())),
                            ("gflops_int", Json::Num(r.gflops(&r.int_on))),
                            ("gflops_f32", Json::Num(r.gflops(&r.int_off))),
                            ("eval_p50_speedup", Json::Num(r.speedup())),
                            ("bit_exact", Json::Bool(r.bit_exact)),
                        ])
                    })
                    .collect(),
            ),
        ),
        ("int_bit_exact", Json::Bool(int_bit_exact)),
    ]);
    let int_md = {
        let mut md = String::from(
            "\n## precision-tiered integer kernels (int-on vs int-off eval)\n\n\
             | net | w/a | coverage | int p50 | f32 p50 | GFLOP/s int | GFLOP/s f32 | \
             speedup | bit-exact |\n|---|---|---|---|---|---|---|---|---|\n",
        );
        for r in &int_rows {
            md += &format!(
                "| {} | {}/{} | {}/{} | {} | {} | {:.2} | {:.2} | x{:.2} | {} |\n",
                r.net,
                r.w_bits,
                r.a_bits,
                r.eligible,
                r.total,
                fmt_time(r.int_on.p50()),
                fmt_time(r.int_off.p50()),
                r.gflops(&r.int_on),
                r.gflops(&r.int_off),
                r.speedup(),
                r.bit_exact,
            );
        }
        md
    };

    // --- machine-readable artifact (schema v9) -------------------------
    let gemm_json = Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("m", Json::Num(r.m as f64)),
                    ("k", Json::Num(r.k as f64)),
                    ("n", Json::Num(r.n as f64)),
                    ("naive_mean_s", Json::Num(r.naive.mean())),
                    ("naive_p50_s", Json::Num(r.naive.p50())),
                    ("blocked_mean_s", Json::Num(r.blocked.mean())),
                    ("blocked_p50_s", Json::Num(r.blocked.p50())),
                    ("pooled_mean_s", Json::Num(r.pooled.mean())),
                    ("pooled_p50_s", Json::Num(r.pooled.p50())),
                    ("speedup", Json::Num(r.speedup())),
                    ("pooled_speedup_vs_scope", Json::Num(r.pooled_speedup_vs_scope())),
                    ("gflops_naive", Json::Num(r.gflops(&r.naive))),
                    ("gflops_blocked", Json::Num(r.gflops(&r.blocked))),
                    ("gflops_pooled", Json::Num(r.gflops(&r.pooled))),
                    ("bit_exact", Json::Bool(r.blocked_exact)),
                    ("pooled_bit_exact", Json::Bool(r.pooled_exact)),
                ])
            })
            .collect(),
    );
    let nets_json = Json::Arr(
        net_rows
            .iter()
            .map(|r| {
                let unfused_speedup = r.eval_p50_speedup_vs_unfused();
                Json::obj(vec![
                    ("net", Json::Str(r.net.clone())),
                    ("eval_batch", Json::Num(r.b as f64)),
                    ("layers", Json::Num(r.nl as f64)),
                    ("residual_adds", Json::Num(r.residual_adds as f64)),
                    ("fused_convs", Json::Num(r.fused_convs as f64)),
                    ("arena_bytes", Json::Num(r.arena_bytes as f64)),
                    ("arena_bytes_unfused", Json::Num(r.arena_bytes_unfused as f64)),
                    ("mean_s", Json::Num(r.pooled.mean())),
                    ("p50_s", Json::Num(r.pooled.p50())),
                    ("p95_s", Json::Num(r.pooled.p95())),
                    ("samples", Json::Num(r.pooled.samples.len() as f64)),
                    ("inf_per_s", Json::Num(r.b as f64 / r.pooled.mean().max(1e-12))),
                    ("unfused_mean_s", Json::Num(r.unfused.mean())),
                    ("unfused_p50_s", Json::Num(r.unfused.p50())),
                    ("eval_p50_speedup_vs_unfused", Json::Num(unfused_speedup)),
                    ("ref_mean_s", Json::Num(r.reference.mean())),
                    ("ref_p50_s", Json::Num(r.reference.p50())),
                    ("ref_p95_s", Json::Num(r.reference.p95())),
                    ("eval_p50_speedup_vs_ref", Json::Num(r.eval_p50_speedup())),
                    ("allocs_per_eval", Json::Num(r.allocs_per_eval)),
                    ("logits_bit_exact", Json::Bool(r.logits_exact)),
                    ("passes_bit_exact", Json::Bool(r.passes_exact)),
                ])
            })
            .collect(),
    );
    let report = Json::obj(vec![
        ("kind", Json::Str("lrmp-bench-simnet".into())),
        ("schema_version", Json::Num(9.0)),
        ("calibrated", Json::Bool(true)),
        ("quick", Json::Bool(quick)),
        ("threads", Json::Num(threads as f64)),
        ("gemm", gemm_json),
        ("mlp_gemm_speedup", Json::Num(mlp_speedup)),
        ("mlp_pooled_speedup", Json::Num(mlp_pooled_speedup)),
        ("pooled_gflops_mean", Json::Num(pooled_gflops_mean)),
        ("conv_lowering_bit_exact", Json::Bool(conv_exact)),
        ("pooled_conv_lowering_bit_exact", Json::Bool(pooled_conv_exact)),
        ("nets", nets_json),
        ("serving", serving_json),
        ("breakdown", breakdown_json),
        ("search", search_json),
        ("overlap", overlap_json),
        ("int_kernels", int_json),
    ]);
    report.to_file(std::path::Path::new(&out_path)).expect("write bench json");
    println!("\nwrote {out_path}");

    // --- committed-baseline regression gate ----------------------------
    let (baseline_ok, summary) = match args.flags.get("baseline") {
        Some(path) => {
            let verdict = compare_with_baseline(path, &rows, pooled_gflops_mean);
            println!("\n{}", verdict.summary);
            (verdict.ok, verdict.summary)
        }
        None => (
            true,
            "## bench-simnet\n\nno `--baseline` given — no comparison was run.\n".to_string(),
        ),
    };
    if let Some(sp) = args.flags.get("summary") {
        std::fs::write(sp, format!("{summary}{search_md}{ov_md}{int_md}"))
            .expect("write bench summary");
        println!("wrote {sp}");
    }

    // --- CI gates ------------------------------------------------------
    let gemm_exact = rows.iter().all(|r| r.blocked_exact && r.pooled_exact);
    let nets_exact = net_rows.iter().all(|r| r.logits_exact);
    let passes_exact = net_rows.iter().all(|r| r.passes_exact);
    if !gemm_exact || !conv_exact || !pooled_conv_exact || !nets_exact || !passes_exact {
        eprintln!(
            "FAIL: a kernel diverged from the naive reference, or the pass-optimized \
             graph executor diverged from the straight-line reference or the \
             passes-off backend"
        );
        std::process::exit(1);
    }
    // Conv+Pool fusion must actually shrink the arena where it fired
    // (and leave it untouched where it did not): conv-tiny fuses its
    // pool, the FC nets and resnet-tiny (whose only pool follows an Add)
    // must not change.
    if !net_rows.iter().all(|r| r.arena_ok()) {
        eprintln!(
            "FAIL: Conv+Pool fusion arena contract violated (a fused net did not \
             shrink its arena, or an unfused net's arena changed)"
        );
        std::process::exit(1);
    }
    if !cost_v1_bitwise_ok {
        eprintln!(
            "FAIL: cost model v2 moved the default-crossbar totals — the schema-v1 \
             closed forms no longer reproduce CostModel::network bit for bit"
        );
        std::process::exit(1);
    }
    let conv_fused = net_rows.iter().any(|r| r.net == "Conv-tiny" && r.fused_convs > 0);
    if !conv_fused {
        eprintln!("FAIL: the pass pipeline did not fuse conv-tiny's Conv+Pool chain");
        std::process::exit(1);
    }
    // The FC path's zero-allocation contract is a hard gate; conv paths
    // are recorded (their sample fan-out makes the contract machine-
    // dependent only via the pool threshold, but the FC path never
    // legitimately allocates).
    let fc_allocs_ok = net_rows
        .iter()
        .filter(|r| !r.has_conv)
        .all(|r| r.allocs_per_eval == 0.0);
    if !fc_allocs_ok {
        eprintln!("FAIL: an FC net's steady-state eval allocated (contract is 0 allocs/eval)");
        std::process::exit(1);
    }
    if !overlap_bit_exact {
        eprintln!(
            "FAIL: overlapped execution diverged bitwise from the serial executor \
             (an eval_pair lane or the overlapped single eval changed a logit)"
        );
        std::process::exit(1);
    }
    if !int_bit_exact {
        eprintln!(
            "FAIL: the integer kernel tier diverged bitwise from the f32 path \
             (int-on logits vs int-off or the straight-line reference changed a bit \
             on an eligible layer)"
        );
        std::process::exit(1);
    }
    if !int_nonvacuous {
        eprintln!(
            "FAIL: the integer-tier gate ran vacuously (no bench layer dispatched to \
             the int tier, or none stayed on the f32 fallback)"
        );
        std::process::exit(1);
    }
    if !search_artifact_identical {
        eprintln!(
            "FAIL: the {search_threads}-thread search's Deployment artifact diverged \
             from the serial run (the fan-out must be bitwise thread-invariant)"
        );
        std::process::exit(1);
    }
    if search_hit_rate <= 0.0 {
        eprintln!("FAIL: the search cost cache recorded no hits");
        std::process::exit(1);
    }
    if !serving_ok {
        eprintln!(
            "FAIL: the multi-route serving smoke left a variant without its routed \
             traffic or without latency percentiles"
        );
        std::process::exit(1);
    }
    if !baseline_ok {
        eprintln!("FAIL: pooled GFLOP/s regressed more than 20% against the committed baseline");
        std::process::exit(1);
    }
    if mlp_pooled_speedup < 1.0 {
        // Not a failure (CI runners are noisy 2-core VMs) but worth flagging.
        println!("note: pooled kernel slower than naive on this machine");
    }
    if let Some(r) = ov_rows.iter().find(|r| r.net == "VGG16") {
        if r.measured_speedup() < 1.0 {
            // Same caveat: the pipelining win needs more worker threads
            // than one eval's conv fan-out can fill.
            println!("note: overlapped VGG-16 pair slower than serial on this machine");
        }
    }
}

fn bits_of(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Re-derives a net's 8-bit baseline totals through the schema-v1 closed
/// forms — no v2 helpers (`dac_stream_phases`, `adc_batches`, `row_phases`,
/// array power factor), just the raw chip fields in the exact v1 evaluation
/// order — and compares against `CostModel::network` bit for bit. The v2
/// breakdowns are a decomposition, not a re-cost: at the identity knobs the
/// two derivations must agree on every bit.
fn v1_totals_bitwise(model: &CostModel, net: &nets::Network, cost: &NetworkCost) -> bool {
    let c = &model.chip;
    let x = c.tile_size;
    let (w_b, a_b) = (8u64, 8u64);
    let mut layer_cycles: Vec<f64> = Vec::new();
    let mut e_tile_sum = 0.0f64;
    let mut e_sram_sum = 0.0f64;
    for l in &net.layers {
        let (r_rows, n_cols, vecs) = (l.lowered_rows(), l.lowered_cols(), l.num_vectors());
        let row_tiles = ceil_div(r_rows, x);
        let col_tiles = ceil_div(n_cols, x);
        let slices = ceil_div(w_b, c.device_bits as u64);
        let tiles = row_tiles * col_tiles * slices;
        // v1 T_tile: vecs · a_b · ceil(X/n_ADC) · ceil(min(R,X)/p) · phase.
        let t_tile = vecs
            * a_b
            * ceil_div(x, c.adcs_per_tile)
            * ceil_div(r_rows.min(x), c.row_parallelism)
            * c.tile_phase_cycles;
        let clusters = ceil_div(tiles, c.tiles_per_cluster()).max(1);
        let in_bits = vecs * r_rows * a_b;
        let t_tile_in = ceil_div(in_bits, c.in_bus_lanes * c.in_bus_bits * clusters);
        let out_bits = vecs * n_cols * row_tiles * slices * ACC_BITS;
        let t_tile_out = ceil_div(out_bits, c.out_bus_lanes * c.out_bus_bits * clusters);
        let d_ops = vecs * n_cols * (row_tiles * slices + 1);
        let t_digital = ceil_div(d_ops, c.lanes_per_vm * clusters);
        // r = 1 everywhere in the baseline, so T_l / r is the exact value.
        layer_cycles.push((t_tile_in + t_tile_out + t_tile + t_digital) as f64);
        e_tile_sum += tiles as f64 * c.tile_power_w * (t_tile as f64) * c.cycle_s();
        let sram_bits = in_bits + 2 * out_bits + vecs * n_cols * a_b;
        e_sram_sum += (sram_bits as f64 / 32.0) * c.sram_access_j;
    }
    let total_cycles: f64 = layer_cycles.iter().sum();
    let e_leak = c.sram_leak_w_per_vm * c.n_vector_modules as f64 * (total_cycles * c.cycle_s());
    let energy_j = e_tile_sum + e_sram_sum + e_leak;
    total_cycles.to_bits() == cost.total_cycles.to_bits()
        && energy_j.to_bits() == cost.energy_j.to_bits()
}

/// Allocations per eval in steady state: warm the arena/caches, then
/// count allocator hits across a window of evals whose inputs were cloned
/// *before* the window (the returned logits ride in the request's own
/// buffer, so the contract is zero on the FC path).
fn allocs_per_eval(backend: &mut SimBackend, x: &[f32], wb: &[f32], ab: &[f32]) -> f64 {
    for _ in 0..3 {
        let y = backend.eval(x.to_vec(), wb.to_vec(), ab.to_vec()).unwrap();
        std::hint::black_box(y);
    }
    const EVALS: usize = 8;
    let xs: Vec<Vec<f32>> = (0..EVALS).map(|_| x.to_vec()).collect();
    let wbs: Vec<Vec<f32>> = (0..EVALS).map(|_| wb.to_vec()).collect();
    let abs_: Vec<Vec<f32>> = (0..EVALS).map(|_| ab.to_vec()).collect();
    let mut outs: Vec<Vec<f32>> = Vec::with_capacity(EVALS);
    let before = ALLOCS.load(Ordering::SeqCst);
    for ((xi, wi), ai) in xs.into_iter().zip(wbs).zip(abs_) {
        outs.push(backend.eval(xi, wi, ai).unwrap());
    }
    let after = ALLOCS.load(Ordering::SeqCst);
    std::hint::black_box(&outs);
    (after - before) as f64 / EVALS as f64
}

/// Outcome of the committed-baseline comparison.
struct BaselineVerdict {
    summary: String,
    ok: bool,
}

/// Compare this run's pooled GFLOP/s against a committed baseline JSON.
/// A missing/unreadable file or a seed placeholder (`calibrated: false`)
/// is a record-only run; a calibrated baseline gates at 20% regression of
/// the aggregate pooled GFLOP/s.
fn compare_with_baseline(path: &str, rows: &[GemmRow], pooled_gflops_mean: f64) -> BaselineVerdict {
    let mut md = String::from("## bench-simnet: pooled kernel vs committed baseline\n\n");
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            md += &format!("baseline `{path}` unreadable ({e}) — record-only run.\n");
            return BaselineVerdict { summary: md, ok: true };
        }
    };
    let base = match Json::parse(&text) {
        Ok(j) => j,
        Err(e) => {
            md += &format!("baseline `{path}` failed to parse ({e:?}) — record-only run.\n");
            return BaselineVerdict { summary: md, ok: true };
        }
    };
    let calibrated = base.get("calibrated").as_bool().unwrap_or(false);
    let base_mean = base.get("pooled_gflops_mean").as_f64();
    if !calibrated || base_mean.is_none() {
        md += "### ⚠️ WARNING: uncalibrated baseline — regression gate NOT armed\n\n\
               The committed repo-root `BENCH_simnet.json` is still a seed placeholder \
               (`calibrated: false`), so the >20% pooled-GFLOP/s regression gate is \
               **record-only**: a kernel regression would pass CI silently.\n\
               Arm it by dispatching the `calibrate-baseline` workflow (Actions tab), or \
               commit a CI bench artifact as `BENCH_simnet.json` at the repo root by hand.\n";
        return BaselineVerdict { summary: md, ok: true };
    }
    let base_mean = base_mean.unwrap();
    md += "| shape | pooled GFLOP/s (now) | baseline | ratio |\n|---|---|---|---|\n";
    for r in rows {
        let now = r.gflops(&r.pooled);
        let b = base
            .get("gemm")
            .as_arr()
            .and_then(|a| a.iter().find(|e| e.get("name").as_str() == Some(r.name.as_str())))
            .and_then(|e| e.get("gflops_pooled").as_f64());
        match b {
            Some(b) => {
                md += &format!(
                    "| {} | {:.2} | {:.2} | x{:.2} |\n",
                    r.name,
                    now,
                    b,
                    now / b.max(1e-12)
                );
            }
            None => {
                md += &format!("| {} | {:.2} | — | — |\n", r.name, now);
            }
        }
    }
    let ratio = pooled_gflops_mean / base_mean.max(1e-12);
    md += &format!(
        "\naggregate pooled GFLOP/s: {pooled_gflops_mean:.2} vs baseline {base_mean:.2} \
         -> x{ratio:.2}\n"
    );
    let ok = ratio >= 0.8;
    md += if ok {
        "verdict: **OK** (within the 20% regression budget)\n"
    } else {
        "verdict: **FAIL** (pooled GFLOP/s regressed more than 20% vs the committed baseline)\n"
    };
    BaselineVerdict { summary: md, ok }
}

/// Fixed-seed conv lowering check: im2col + the given kernel must equal
/// the direct-convolution reference bit for bit (same reduction order).
/// `pool`: `None` runs the PR 2 scope kernel, `Some` the pooled one.
fn conv_lowering_bit_exact(pool: Option<&WorkerPool>) -> bool {
    let g = ConvGeom {
        in_c: 8,
        out_c: 16,
        kernel: 3,
        stride: 1,
        padding: 1,
        in_hw: 12,
        out_hw: 12,
    };
    let mut rng = Rng::new(0x5EED);
    let x: Vec<f32> = (0..g.in_features())
        .map(|i| {
            if i % 5 == 0 {
                0.0
            } else {
                (rng.normal() * 0.5) as f32
            }
        })
        .collect();
    let w: Vec<f32> = (0..g.patch_len() * g.out_c)
        .map(|_| (rng.normal() * 0.2) as f32)
        .collect();

    let npos = g.num_positions();
    let mut direct = vec![0f32; g.out_c * npos];
    gemm::conv2d_ref(&x, &w, &g, &mut direct);

    let packed = PackedMat::pack(&w, g.patch_len(), g.out_c);
    let mut lowered = vec![0f32; g.out_c * npos];
    let chunk = 32usize;
    let mut patches = vec![0f32; chunk * g.patch_len()];
    let mut prod = vec![0f32; chunk * g.out_c];
    let mut pos0 = 0;
    while pos0 < npos {
        let m = chunk.min(npos - pos0);
        gemm::im2col_chunk(&x, &g, pos0, m, &mut patches[..m * g.patch_len()]);
        match pool {
            Some(p) => gemm::matmul_pooled(
                &patches[..m * g.patch_len()],
                &packed,
                m,
                p,
                &mut prod[..m * g.out_c],
            ),
            None => gemm::matmul_blocked(
                &patches[..m * g.patch_len()],
                &packed,
                m,
                &mut prod[..m * g.out_c],
            ),
        }
        for p in 0..m {
            for oc in 0..g.out_c {
                lowered[oc * npos + pos0 + p] = prod[p * g.out_c + oc];
            }
        }
        pos0 += m;
    }
    bits_of(&direct) == bits_of(&lowered)
}
