//! Sim-backend hot-path benchmark: the naive triple-loop quantized matmul
//! vs the blocked kernel (`runtime::gemm`) over the paper MLP's layer
//! shapes, plus end-to-end `SimBackend` eval latency per network. Emits a
//! machine-readable `BENCH_simnet.json` (schema documented in
//! `rust/src/api/README.md`) that the CI `bench-smoke` job uploads.
//!
//! Plain `fn main` bench (`harness = false`):
//!
//!   cargo bench --bench bench_simnet -- [--quick] [--out FILE]
//!
//! `--quick` shrinks the sample budgets for the CI smoke job. The run
//! **fails (exit 1) if the blocked kernel's output ever diverges bitwise
//! from the naive reference** — correctness is the CI gate, the latency
//! numbers are the uploaded artifact.

use lrmp::bench_harness::{fmt_time, Bencher, Table};
use lrmp::cli::Args;
use lrmp::coordinator::InferenceBackend;
use lrmp::nets;
use lrmp::runtime::gemm::{self, ConvGeom, PackedMat};
use lrmp::runtime::simnet::SimBackend;
use lrmp::util::json::Json;
use lrmp::util::prng::Rng;
use std::time::Duration;

/// One naive-vs-blocked GEMM comparison row.
struct GemmRow {
    name: String,
    m: usize,
    k: usize,
    n: usize,
    naive: lrmp::bench_harness::BenchResult,
    blocked: lrmp::bench_harness::BenchResult,
    bit_exact: bool,
}

impl GemmRow {
    fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.k as f64 * self.n as f64
    }
    fn speedup(&self) -> f64 {
        self.naive.mean() / self.blocked.mean().max(1e-12)
    }
    fn gflops(&self, r: &lrmp::bench_harness::BenchResult) -> f64 {
        self.flops() / r.mean().max(1e-12) / 1e9
    }
}

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    // `cargo bench` forwards a bare `--bench` to harness=false targets.
    let args = Args::parse_with_switches(raw, &["quick", "bench"]);
    let quick = args.bool("quick");
    let out_path = args.str("out", "BENCH_simnet.json");

    let bench = if quick {
        Bencher {
            warmup: Duration::from_millis(10),
            min_time: Duration::from_millis(60),
            min_samples: 3,
            max_samples: 40,
        }
    } else {
        Bencher::default()
    };

    println!(
        "=== sim backend hot path: naive vs blocked quantized matmul ===\n\
         (threads {}, {} profile)\n",
        gemm::worker_threads(),
        if quick { "quick" } else { "full" }
    );

    // --- GEMM kernel comparison over the paper MLP's layer shapes ------
    let batch = 16usize;
    let dims = [784usize, 1024, 4096, 4096, 1024, 10];
    let mut rng = Rng::new(0xBE7C);
    let mut rows: Vec<GemmRow> = Vec::new();
    for (l, w) in dims.windows(2).enumerate() {
        let (k, n) = (w[0], w[1]);
        // Post-ReLU-like inputs: ~1/3 exact zeros, the rest positive.
        let x: Vec<f32> = (0..batch * k)
            .map(|i| {
                if i % 3 == 0 {
                    0.0
                } else {
                    (rng.f64() * 0.9 + 0.05) as f32
                }
            })
            .collect();
        let wm: Vec<f32> = (0..k * n).map(|_| (rng.normal() * 0.1) as f32).collect();
        let packed = PackedMat::pack(&wm, k, n);

        let mut y_naive = vec![0f32; batch * n];
        let mut y_blocked = vec![0f32; batch * n];
        gemm::matmul_naive(&x, &wm, batch, k, n, &mut y_naive);
        gemm::matmul_blocked(&x, &packed, batch, &mut y_blocked);
        let bit_exact = bits_of(&y_naive) == bits_of(&y_blocked);

        let name = format!("fc{} {}x{}x{}", l + 1, batch, k, n);
        let naive = bench.run(&format!("{name} naive"), || {
            gemm::matmul_naive(&x, &wm, batch, k, n, &mut y_naive);
        });
        let blocked = bench.run(&format!("{name} blocked"), || {
            gemm::matmul_blocked(&x, &packed, batch, &mut y_blocked);
        });
        rows.push(GemmRow {
            name,
            m: batch,
            k,
            n,
            naive,
            blocked,
            bit_exact,
        });
    }

    let naive_total: f64 = rows.iter().map(|r| r.naive.mean()).sum();
    let blocked_total: f64 = rows.iter().map(|r| r.blocked.mean()).sum();
    let mlp_speedup = naive_total / blocked_total.max(1e-12);

    let mut t = Table::new(&["shape", "naive", "blocked", "speedup", "GFLOP/s", "bit-exact"]);
    for r in &rows {
        t.row(&[
            r.name.clone(),
            fmt_time(r.naive.mean()),
            fmt_time(r.blocked.mean()),
            format!("x{:.2}", r.speedup()),
            format!("{:.2}", r.gflops(&r.blocked)),
            r.bit_exact.to_string(),
        ]);
    }
    t.print();
    println!(
        "\nMLP eval path (sum of layer GEMMs, batch {batch}): naive {} vs blocked {} -> x{:.2}\n",
        fmt_time(naive_total),
        fmt_time(blocked_total),
        mlp_speedup
    );

    // --- conv lowering correctness (im2col + blocked vs direct conv) ---
    let conv_exact = conv_lowering_bit_exact();
    println!("conv lowering im2col+blocked == direct reference: {conv_exact}\n");

    // --- end-to-end SimBackend eval latency per network ----------------
    let net_bench = if quick {
        Bencher {
            warmup: Duration::from_millis(10),
            min_time: Duration::from_millis(80),
            min_samples: 3,
            max_samples: 20,
        }
    } else {
        Bencher::quick()
    };
    let mut net_rows = Vec::new();
    for name in ["mlp-tiny", "mlp", "conv-tiny"] {
        let net = nets::by_name(name).expect("bench nets are registered");
        let b = 16usize;
        let mut backend = SimBackend::from_network(&net, b, 7).expect("sim-supported net");
        let dim = backend.input_dim();
        let nl = backend.num_layers();
        let x: Vec<f32> = (0..b * dim).map(|i| ((i * 31) % 97) as f32 / 97.0).collect();
        let (wb, ab) = (vec![5.0f32; nl], vec![6.0f32; nl]);
        let res = net_bench.run(&format!("eval {} b={b}", net.name), || {
            let y = backend.eval(x.clone(), wb.clone(), ab.clone()).unwrap();
            std::hint::black_box(y);
        });
        println!(
            "  -> {} {:.1} inferences/s (p95 {})",
            net.name,
            b as f64 / res.mean().max(1e-12),
            fmt_time(res.p95())
        );
        net_rows.push((net.name.clone(), b, nl, res));
    }

    // --- machine-readable artifact -------------------------------------
    let gemm_json = Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("m", Json::Num(r.m as f64)),
                    ("k", Json::Num(r.k as f64)),
                    ("n", Json::Num(r.n as f64)),
                    ("naive_mean_s", Json::Num(r.naive.mean())),
                    ("naive_p50_s", Json::Num(r.naive.p50())),
                    ("blocked_mean_s", Json::Num(r.blocked.mean())),
                    ("blocked_p50_s", Json::Num(r.blocked.p50())),
                    ("speedup", Json::Num(r.speedup())),
                    ("gflops_naive", Json::Num(r.gflops(&r.naive))),
                    ("gflops_blocked", Json::Num(r.gflops(&r.blocked))),
                    ("bit_exact", Json::Bool(r.bit_exact)),
                ])
            })
            .collect(),
    );
    let nets_json = Json::Arr(
        net_rows
            .iter()
            .map(|(name, b, nl, res)| {
                Json::obj(vec![
                    ("net", Json::Str(name.clone())),
                    ("eval_batch", Json::Num(*b as f64)),
                    ("layers", Json::Num(*nl as f64)),
                    ("mean_s", Json::Num(res.mean())),
                    ("p50_s", Json::Num(res.p50())),
                    ("p95_s", Json::Num(res.p95())),
                    ("samples", Json::Num(res.samples.len() as f64)),
                    ("inf_per_s", Json::Num(*b as f64 / res.mean().max(1e-12))),
                ])
            })
            .collect(),
    );
    let report = Json::obj(vec![
        ("kind", Json::Str("lrmp-bench-simnet".into())),
        ("schema_version", Json::Num(1.0)),
        ("quick", Json::Bool(quick)),
        ("threads", Json::Num(gemm::worker_threads() as f64)),
        ("gemm", gemm_json),
        ("mlp_gemm_speedup", Json::Num(mlp_speedup)),
        ("conv_lowering_bit_exact", Json::Bool(conv_exact)),
        ("nets", nets_json),
    ]);
    report.to_file(std::path::Path::new(&out_path)).expect("write bench json");
    println!("\nwrote {out_path}");

    // --- CI gate: bitwise correctness, not speed -----------------------
    let gemm_exact = rows.iter().all(|r| r.bit_exact);
    if !gemm_exact || !conv_exact {
        eprintln!("FAIL: blocked kernel diverged from the naive reference");
        std::process::exit(1);
    }
    if mlp_speedup < 1.0 {
        // Not a failure (CI runners are noisy 2-core VMs) but worth flagging.
        println!("note: blocked kernel slower than naive on this machine");
    }
}

fn bits_of(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Fixed-seed conv lowering check: im2col + blocked matmul must equal the
/// direct-convolution reference bit for bit (same reduction order).
fn conv_lowering_bit_exact() -> bool {
    let g = ConvGeom {
        in_c: 8,
        out_c: 16,
        kernel: 3,
        stride: 1,
        padding: 1,
        in_hw: 12,
        out_hw: 12,
    };
    let mut rng = Rng::new(0x5EED);
    let x: Vec<f32> = (0..g.in_features())
        .map(|i| {
            if i % 5 == 0 {
                0.0
            } else {
                (rng.normal() * 0.5) as f32
            }
        })
        .collect();
    let w: Vec<f32> = (0..g.patch_len() * g.out_c)
        .map(|_| (rng.normal() * 0.2) as f32)
        .collect();

    let npos = g.num_positions();
    let mut direct = vec![0f32; g.out_c * npos];
    gemm::conv2d_ref(&x, &w, &g, &mut direct);

    let packed = PackedMat::pack(&w, g.patch_len(), g.out_c);
    let mut lowered = vec![0f32; g.out_c * npos];
    let chunk = 32usize;
    let mut patches = vec![0f32; chunk * g.patch_len()];
    let mut prod = vec![0f32; chunk * g.out_c];
    let mut pos0 = 0;
    while pos0 < npos {
        let m = chunk.min(npos - pos0);
        gemm::im2col_chunk(&x, &g, pos0, m, &mut patches[..m * g.patch_len()]);
        gemm::matmul_blocked(&patches[..m * g.patch_len()], &packed, m, &mut prod[..m * g.out_c]);
        for p in 0..m {
            for oc in 0..g.out_c {
                lowered[oc * npos + pos0 + p] = prod[p * g.out_c + oc];
            }
        }
        pos0 += m;
    }
    bits_of(&direct) == bits_of(&lowered)
}
