//! §Perf hot-path microbenchmarks (EXPERIMENTS.md §Perf): the operations the
//! LRMP search loop and the runtime execute millions / thousands of times.
//! Targets (DESIGN.md §9):
//!   cost-model ≥ 10^6 layer-evals/s; latencyOptim LP (RN101) ≤ 10 ms;
//!   DDPG act ≤ 20 µs, update ≤ 2 ms; simulator ≥ 10^5 events/s;
//!   PJRT accuracy-eval dominated by XLA compute.

use lrmp::bench_harness::Bencher;
use lrmp::cost::CostModel;
use lrmp::lp::mckp::{self, Choice};
use lrmp::nets;
use lrmp::quant::{LayerPrecision, Policy};
use lrmp::replication::{self, LayerSummary, Objective};
use lrmp::rl::ddpg::{Ddpg, DdpgConfig, Transition};
use lrmp::rl::env::OBS_DIM;
use lrmp::runtime;
use lrmp::runtime::gemm::{self, PackedMat};
use lrmp::runtime::pool::WorkerPool;
use lrmp::sim;
use lrmp::util::json::Json;
use lrmp::util::prng::Rng;

fn main() {
    let b = Bencher::default();
    let model = CostModel::paper();
    let rn18 = nets::resnet::resnet18();
    let rn101 = nets::resnet::resnet101();

    println!("=== L3 hot-path microbenchmarks ===\n");

    // --- cost model ---
    let policy18 = Policy::baseline(rn18.num_layers());
    let repl18 = vec![1u64; rn18.num_layers()];
    let layer = &rn18.layers[5];
    let prec = LayerPrecision::new(5, 6);
    let r = b.run("cost: single layer eval", || {
        std::hint::black_box(model.layer(layer, prec));
    });
    println!("  -> {:.2} M layer-evals/s\n", r.throughput() / 1e6);
    b.run("cost: full RN18 network eval", || {
        std::hint::black_box(model.network(&rn18, &policy18, &repl18));
    });

    // --- replication optimizers ---
    let costs18 = model.layers(&rn18, &policy18);
    let sum18 = LayerSummary::from_costs(&costs18);
    let quant101 = Policy::uniform(rn101.num_layers(), 4, 4);
    let costs101 = model.layers(&rn101, &quant101);
    let sum101 = LayerSummary::from_costs(&costs101);
    let tiles18 = rn18.tiles_at_uniform(256, 8, 1);
    let tiles101 = rn101.tiles_at_uniform(256, 8, 1);
    let r = b.run("LP: latencyOptim MCKP-DP RN18", || {
        std::hint::black_box(replication::latency_optim(&sum18, tiles18).unwrap());
    });
    let rn18_ms = r.mean() * 1e3;
    let r = b.run("LP: latencyOptim MCKP-DP RN101@4b", || {
        std::hint::black_box(replication::latency_optim(&sum101, tiles101).unwrap());
    });
    let rn101_ms = r.mean() * 1e3;
    b.run("LP: throughputOptim bisect RN101@4b", || {
        std::hint::black_box(replication::throughput_optim(&sum101, tiles101).unwrap());
    });
    b.run("LP: greedy (enforcement inner) RN101@4b", || {
        std::hint::black_box(
            replication::greedy(&sum101, tiles101, Objective::Latency).unwrap(),
        );
    });
    println!(
        "  -> exact DP: RN18 {rn18_ms:.2} ms, RN101 {rn101_ms:.2} ms (target ≤ 10 ms)\n"
    );

    // --- raw MCKP kernel ---
    let mut rng = Rng::new(3);
    let groups: Vec<Vec<Choice>> = (0..40)
        .map(|_| {
            (1..=24u64)
                .map(|r| Choice {
                    weight: rng.int_range(1, 12) as u64 * r,
                    cost: 1e6 / r as f64,
                })
                .collect()
        })
        .collect();
    b.run("LP: raw MCKP 40 groups x 24 choices, cap 2000", || {
        std::hint::black_box(mckp::solve(&groups, 2000));
    });

    // --- DDPG agent ---
    let mut agent = Ddpg::new(DdpgConfig::default_for(OBS_DIM, 2, 1));
    let obs = vec![0.3; OBS_DIM];
    for _ in 0..256 {
        agent.replay.push(Transition {
            state: obs.clone(),
            action: vec![0.5, 0.5],
            reward: 0.1,
            next_state: obs.clone(),
            terminal: false,
        });
    }
    let r = b.run("RL: DDPG act", || {
        std::hint::black_box(agent.act(&obs));
    });
    println!("  -> act {:.1} us (target ≤ 20 us)\n", r.mean() * 1e6);
    let r = b.run("RL: DDPG minibatch update", || {
        std::hint::black_box(agent.update());
    });
    println!("  -> update {:.2} ms (target ≤ 2 ms)\n", r.mean() * 1e3);

    // --- simulator ---
    let conv = &rn18.layers[8];
    let sim_res = sim::simulate_layer(&model, conv, LayerPrecision::new(8, 8), 2);
    let r = b.run("sim: event-driven layer (conv, r=2)", || {
        std::hint::black_box(sim::simulate_layer(
            &model,
            conv,
            LayerPrecision::new(8, 8),
            2,
        ));
    });
    println!(
        "  -> {:.2} M events/s (target ≥ 0.1 M)\n",
        sim_res.events as f64 / r.mean() / 1e6
    );

    // --- sim serving hot path: pool dispatch vs thread::scope spawn ---
    let threads = gemm::worker_threads();
    let pool = WorkerPool::new(threads);
    let parts = threads.max(2);
    let r = b.run("pool: dispatch trivial job (persistent workers)", || {
        pool.run(parts, |p| {
            std::hint::black_box(p);
        });
    });
    let pool_us = r.mean() * 1e6;
    let r = b.run("pool: thread::scope spawn equivalent", || {
        std::thread::scope(|s| {
            for p in 0..parts {
                s.spawn(move || std::hint::black_box(p));
            }
        });
    });
    println!(
        "  -> pool dispatch {pool_us:.1} us vs scope spawn {:.1} us ({parts} parts, \
         {threads} threads)\n",
        r.mean() * 1e6
    );
    let (m, k, n) = (16usize, 1024usize, 1024usize);
    let x: Vec<f32> = (0..m * k).map(|i| ((i * 7) % 19) as f32 / 19.0).collect();
    let wm: Vec<f32> = (0..k * n).map(|i| ((i * 11) % 23) as f32 / 23.0 - 0.5).collect();
    let packed = PackedMat::pack(&wm, k, n);
    let mut y = vec![0f32; m * n];
    let r = b.run("gemm: scope kernel 16x1024x1024", || {
        gemm::matmul_blocked(&x, &packed, m, &mut y);
    });
    let scope_s = r.mean();
    let r = b.run("gemm: pooled tiled kernel 16x1024x1024", || {
        gemm::matmul_pooled(&x, &packed, m, &pool, &mut y);
    });
    println!(
        "  -> pooled kernel x{:.2} over the scope kernel on the serving shape\n",
        scope_s / r.mean().max(1e-12)
    );

    // --- JSON substrate ---
    let payload = Json::obj(vec![
        ("policy", Policy::uniform(105, 5, 6).to_json()),
        ("trajectory", Json::arr_f64(&vec![1.25; 256])),
    ])
    .pretty();
    b.run("util: JSON parse 105-layer report", || {
        std::hint::black_box(Json::parse(&payload).unwrap());
    });

    // --- PJRT request path (requires artifacts) ---
    let dir = runtime::default_artifacts_dir();
    if dir.join("manifest.json").exists() {
        println!("\n=== PJRT request path (artifacts found) ===\n");
        let engine = lrmp::runtime::engine::Engine::start(dir).expect("engine");
        let bsz = engine.eval_batch * engine.input_dim;
        let x: Vec<f32> = (0..bsz).map(|i| (i % 97) as f32 / 97.0).collect();
        let wb = vec![5.0f32; engine.num_layers];
        let ab = vec![6.0f32; engine.num_layers];
        let quick = Bencher::quick();
        let r = quick.run("runtime: eval 256-batch quantized infer", || {
            std::hint::black_box(
                engine.eval(x.clone(), wb.clone(), ab.clone()).unwrap(),
            );
        });
        println!(
            "  -> {:.1} inferences/s through the full PJRT path ({} samples/batch)",
            engine.eval_batch as f64 * r.throughput(),
            engine.eval_batch
        );
        let xt: Vec<f32> = (0..engine.train_batch * engine.input_dim)
            .map(|i| (i % 89) as f32 / 89.0)
            .collect();
        let mut onehot = vec![0.0f32; engine.train_batch * engine.num_classes];
        for i in 0..engine.train_batch {
            onehot[i * engine.num_classes + i % engine.num_classes] = 1.0;
        }
        quick.run("runtime: finetune step (fwd+bwd+sgd)", || {
            std::hint::black_box(
                engine
                    .train_step(xt.clone(), onehot.clone(), wb.clone(), ab.clone(), 0.0)
                    .unwrap(),
            );
        });
    } else {
        println!("\n(PJRT benches skipped: run `make artifacts` first)");
    }
}
