//! Fig 2 reproduction: the §III motivating experiment on ResNet-18 —
//! (a) the 8-bit baseline breakdown, (b) selective 6-bit quantization,
//! (c) naive replication of the bottleneck — with the paper's numbers
//! asserted as tolerances.

use lrmp::bench_harness::Table;
use lrmp::cost::CostModel;
use lrmp::nets;
use lrmp::quant::Policy;

fn main() {
    let net = nets::resnet::resnet18();
    let model = CostModel::paper();
    let nl = net.num_layers();
    let base = model.baseline(&net);

    println!("=== Fig 2(a): baseline per-layer breakdown (top 6 by latency) ===\n");
    let mut idx: Vec<usize> = (0..nl).collect();
    idx.sort_by(|&a, &b| {
        base.layers[b]
            .total_cycles()
            .cmp(&base.layers[a].total_cycles())
    });
    let mut t = Table::new(&["layer", "tiles", "Mcycles", "share %"]);
    for &i in idx.iter().take(6) {
        t.row(&[
            net.layers[i].name.clone(),
            base.layers[i].tiles.to_string(),
            format!("{:.2}", base.layers[i].total_cycles() as f64 / 1e6),
            format!(
                "{:.1}",
                100.0 * base.layers[i].total_cycles() as f64 / base.total_cycles
            ),
        ]);
    }
    t.print();
    assert_eq!(base.bottleneck_layer, 0, "conv1 must bottleneck the baseline");

    // (b) selective quantization.
    let heavy = net
        .layers
        .iter()
        .position(|l| l.name == "layer4.1.conv2")
        .unwrap();
    let mut p = Policy::baseline(nl);
    p.layers[heavy].w_bits = 6;
    p.layers[0].a_bits = 6;
    let q = model.network(&net, &p, &vec![1; nl]);
    let freed = base.tiles_used - q.tiles_used;
    let lat_b = 100.0 * (1.0 - q.total_cycles / base.total_cycles);
    let thr_b = q.throughput() / base.throughput();

    // (c) naive replication.
    let copies = freed / q.layers[0].tiles;
    let mut repl = vec![1u64; nl];
    repl[0] += copies;
    let r = model.network(&net, &p, &repl);
    let lat_c = 100.0 * (1.0 - r.total_cycles / base.total_cycles);
    let thr_c = r.throughput() / base.throughput();

    println!("\n=== Fig 2(b)/(c): paper vs measured ===\n");
    let mut t2 = Table::new(&["quantity", "paper", "ours"]);
    t2.row(&["(b) tiles conserved".into(), "72".into(), freed.to_string()]);
    t2.row(&["(b) latency reduction".into(), "5.7%".into(), format!("{lat_b:.1}%")]);
    t2.row(&["(b) throughput gain".into(), "1.33x".into(), format!("{thr_b:.2}x")]);
    t2.row(&["(c) extra conv1 copies".into(), "9".into(), copies.to_string()]);
    t2.row(&["(c) latency reduction".into(), "25.5%".into(), format!("{lat_c:.1}%")]);
    t2.row(&["(c) throughput gain".into(), "2.34x".into(), format!("{thr_c:.2}x")]);
    t2.print();

    // Shape assertions (see EXPERIMENTS.md for the discussion).
    assert_eq!(freed, 72, "Eqn-2 tile conservation must match exactly");
    assert!((thr_b - 1.33).abs() < 0.02, "throughput(b) {thr_b}");
    assert_eq!(copies, 9, "naive replication copy count");
    assert!((thr_c - 2.34).abs() < 0.05, "throughput(c) {thr_c}");
    assert!((3.0..9.0).contains(&lat_b), "latency(b) {lat_b}% vs paper 5.7%");
    assert!((20.0..32.0).contains(&lat_c), "latency(c) {lat_c}% vs paper 25.5%");
    println!("\nall Fig 2 assertions passed");
}
