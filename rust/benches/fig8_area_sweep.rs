//! Fig 8 reproduction: sensitivity of ResNet-18 latency improvements to the
//! chip-area (tile) constraint, for quantization-only, replication-only,
//! and joint LRMP. Paper observations to match:
//!   - mixed precision alone: ~18.5% latency reduction using 39% fewer tiles
//!   - joint: ~49% latency reduction using 35% fewer tiles
//!   - replication alone: ~32% reduction but needs ≥ baseline tiles (+5%)
//!   - below baseline area, replication-only is infeasible
//!   - with all tiles available, joint ≈ 2× the improvement of repl-only

use lrmp::bench_harness::Table;
use lrmp::cost::CostModel;
use lrmp::lrmp::ablation::{self, AblationCell};
use lrmp::nets;

fn get(cells: &[AblationCell], name: &str) -> Option<(f64, u64)> {
    cells.iter().find(|(n, _)| *n == name).and_then(|(_, v)| *v)
}

fn main() {
    let net = nets::resnet::resnet18();
    let model = CostModel::paper();
    let base_tiles = net.tiles_at_uniform(model.chip.tile_size, 8, model.chip.device_bits);
    let episodes = std::env::var("LRMP_EPISODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(24);
    println!(
        "=== Fig 8: area sensitivity, ResNet18 (baseline {base_tiles} tiles, \
         {episodes} episodes/mode) ===\n"
    );

    let mut t = Table::new(&[
        "area x baseline",
        "quant-only",
        "repl-only",
        "joint",
        "joint tiles used",
    ]);
    let fractions = [0.6, 0.8, 1.0, 1.2, 1.5];
    let mut at_1x: Option<Vec<AblationCell>> = None;
    let mut below: Option<Vec<AblationCell>> = None;
    for frac in fractions {
        let n_tiles = (base_tiles as f64 * frac) as u64;
        let cells = ablation::area_modes(&model, &net, n_tiles, 7, episodes);
        let fmt = |name: &str| {
            get(&cells, name)
                .map(|(x, _)| format!("x{x:.2}"))
                .unwrap_or_else(|| "infeasible".into())
        };
        t.row(&[
            format!("{frac:.1}"),
            fmt("quant-only"),
            fmt("repl-only"),
            fmt("joint"),
            get(&cells, "joint")
                .map(|(_, u)| u.to_string())
                .unwrap_or_else(|| "-".into()),
        ]);
        if (frac - 1.0).abs() < 1e-9 {
            at_1x = Some(cells.clone());
        }
        if (frac - 0.6).abs() < 1e-9 {
            below = Some(cells.clone());
        }
    }
    t.print();

    let at_1x = at_1x.unwrap();
    let below = below.unwrap();
    let quant_1x = get(&at_1x, "quant-only").expect("quant-only feasible at 1.0x");
    let repl_1x = get(&at_1x, "repl-only").expect("repl-only feasible at 1.0x");
    let joint_1x = get(&at_1x, "joint").expect("joint feasible at 1.0x");

    println!(
        "\npaper anchors: quant-only −18.5% (x1.23), repl-only −32% (x1.47), joint −49% (x1.96+)"
    );
    println!(
        "ours at 1.0x area: quant-only x{:.2}, repl-only x{:.2}, joint x{:.2}",
        quant_1x.0, repl_1x.0, joint_1x.0
    );

    // Shape assertions.
    assert!(
        get(&below, "repl-only").is_none(),
        "replication-only must be infeasible below the baseline area"
    );
    assert!(
        get(&below, "joint").is_some() && get(&below, "quant-only").is_some(),
        "quantization must keep the mapping feasible at 0.6x area"
    );
    assert!(
        joint_1x.0 > quant_1x.0 && joint_1x.0 > repl_1x.0,
        "joint must beat both single dimensions at iso-area"
    );
    assert!(
        joint_1x.0 >= 1.5 * repl_1x.0,
        "joint ({:.2}) should be well above repl-only ({:.2}) — paper reports ~2x",
        joint_1x.0,
        repl_1x.0
    );
    println!("\nall Fig 8 shape assertions passed");
}
