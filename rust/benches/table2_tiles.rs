//! Table II reproduction: baseline (8-bit) tile counts of the five DNN
//! benchmarks, paper vs our Eqn-2 evaluation, plus a microbenchmark of the
//! tile-count computation (a cost-model hot path).

use lrmp::bench_harness::{Bencher, Table};
use lrmp::cost::CostModel;
use lrmp::nets;

fn main() {
    println!("=== Table II: DNN benchmarks, 8-bit baseline tile counts ===\n");
    let paper = [
        ("MLP", "MNIST", 3232u64),
        ("ResNet18", "ImageNet", 1602),
        ("ResNet34", "ImageNet", 2965),
        ("ResNet50", "ImageNet", 3370),
        ("ResNet101", "ImageNet", 5682),
    ];
    let model = CostModel::paper();
    let mut t = Table::new(&["benchmark", "dataset", "paper", "ours", "delta"]);
    let mut max_rel = 0.0f64;
    for (name, ds, p) in paper {
        let net = nets::by_name(name).unwrap();
        let ours = net.tiles_at_uniform(model.chip.tile_size, 8, model.chip.device_bits);
        let delta = ours as i64 - p as i64;
        max_rel = max_rel.max(delta.unsigned_abs() as f64 / p as f64);
        t.row(&[
            name.to_string(),
            ds.to_string(),
            p.to_string(),
            ours.to_string(),
            format!("{delta:+}"),
        ]);
    }
    t.print();
    println!(
        "\nmax relative deviation: {:.3}% (MLP exact; ResNet deltas stem from \
         downsample-projection tallying, see DESIGN.md §5)",
        100.0 * max_rel
    );
    assert!(max_rel < 0.01, "tile counts must match the paper within 1%");

    println!("\n--- microbenchmark: Eqn-2 tile accounting ---");
    let net = nets::by_name("resnet101").unwrap();
    let b = Bencher::default();
    let r = b.run("tiles_at_uniform(resnet101)", || {
        std::hint::black_box(net.tiles_at_uniform(256, 8, 1));
    });
    println!(
        "=> {:.1}k full-network tile evaluations / second",
        r.throughput() / 1e3
    );
}
